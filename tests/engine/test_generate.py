"""Generation engine: behaviour-logprob consistency, eos stopping,
row budgets, initial_done skipping, left-padding invariance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.engine.generate import GenerateConfig, generate, positions_from_mask, score
from repro.models import model as M


@pytest.fixture(scope="module")
def setup(request):
    from repro.models.config import ModelConfig
    cfg = ModelConfig(name="t", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=32)
    params = M.init_lm(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompt(cfg, B=3, P=8, seed=1):
    prompt = jax.random.randint(jax.random.PRNGKey(seed), (B, P), 3,
                                cfg.vocab_size)
    mask = np.ones((B, P), bool)
    mask[0, :3] = False
    mask[2, :1] = False
    mask = jnp.asarray(mask)
    return jnp.where(mask, prompt, 0), mask


def test_logprobs_match_rescoring(setup):
    cfg, params = setup
    prompt, mask = _prompt(cfg)
    gen = GenerateConfig(max_new_tokens=10)
    out = generate(params, cfg, gen, prompt, mask, jax.random.PRNGKey(7))
    N = 10
    full = jnp.concatenate([prompt, out["tokens"]], axis=1)
    gmask = jnp.arange(N)[None, :] < out["length"][:, None]
    fmask = jnp.concatenate([mask, gmask], axis=1)
    sc = score(params, cfg, full, fmask)
    err = jnp.max(jnp.abs(jnp.where(gmask, sc["logprobs"][:, prompt.shape[1]:]
                                    - out["logprobs"], 0.0)))
    assert float(err) < 1e-4


def test_eos_stops_row(setup):
    cfg, params = setup
    prompt, mask = _prompt(cfg)
    gen = GenerateConfig(max_new_tokens=16, eos_id=2)
    out = generate(params, cfg, gen, prompt, mask, jax.random.PRNGKey(3))
    toks = np.asarray(out["tokens"])
    lens = np.asarray(out["length"])
    for i in range(toks.shape[0]):
        row = toks[i, :lens[i]]
        if 2 in row.tolist():
            assert row.tolist().index(2) == lens[i] - 1  # eos is last
        assert (toks[i, lens[i]:] == 0).all()            # pads after


def test_row_budget(setup):
    cfg, params = setup
    prompt, mask = _prompt(cfg)
    gen = GenerateConfig(max_new_tokens=16, eos_id=31)  # unlikely eos
    budget = jnp.array([4, 0, 9], jnp.int32)
    out = generate(params, cfg, gen, prompt, mask, jax.random.PRNGKey(5),
                   row_budget=budget)
    assert (np.asarray(out["length"]) <= np.asarray(budget)).all()
    assert int(out["length"][1]) == 0


def test_initial_done_skips_rows(setup):
    cfg, params = setup
    prompt, mask = _prompt(cfg)
    gen = GenerateConfig(max_new_tokens=8)
    done = jnp.array([True, False, True])
    out = generate(params, cfg, gen, prompt, mask, jax.random.PRNGKey(5),
                   initial_done=done)
    lens = np.asarray(out["length"])
    assert lens[0] == 0 and lens[2] == 0 and lens[1] > 0


def test_left_padding_invariance(setup):
    """Extra left padding must not change greedy generation."""
    cfg, params = setup
    B, P = 1, 6
    prompt = jax.random.randint(jax.random.PRNGKey(9), (B, P), 3,
                                cfg.vocab_size)
    mask = jnp.ones((B, P), bool)
    gen = GenerateConfig(max_new_tokens=6, temperature=0.0)
    out1 = generate(params, cfg, gen, prompt, mask, jax.random.PRNGKey(0))
    pad = jnp.zeros((B, 3), jnp.int32)
    prompt2 = jnp.concatenate([pad, prompt], axis=1)
    mask2 = jnp.concatenate([jnp.zeros((B, 3), bool), mask], axis=1)
    out2 = generate(params, cfg, gen, prompt2, mask2, jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(out1["tokens"]),
                                  np.asarray(out2["tokens"]))


def test_resume_from_cache_matches_generate(setup):
    """Decoding from an externally prefilled cache == prefill-inside-generate
    for the same key: the two engine entry points share one decode loop."""
    from repro.engine.generate import resume_from_cache
    cfg, params = setup
    prompt, mask = _prompt(cfg)
    B, P = prompt.shape
    N = 10
    gen = GenerateConfig(max_new_tokens=N)
    key = jax.random.PRNGKey(11)
    want = generate(params, cfg, gen, prompt, mask, key)

    caches = M.init_cache(cfg, B, P + N)
    logits, caches = M.prefill(params, cfg, prompt,
                               positions_from_mask(mask), caches)
    got = resume_from_cache(params, cfg, gen, caches, logits[:, -1],
                            mask.sum(axis=1).astype(jnp.int32), P, key)
    np.testing.assert_array_equal(np.asarray(got["tokens"]),
                                  np.asarray(want["tokens"]))
    np.testing.assert_array_equal(np.asarray(got["length"]),
                                  np.asarray(want["length"]))
    np.testing.assert_allclose(np.asarray(got["logprobs"]),
                               np.asarray(want["logprobs"]), atol=1e-6)


def test_score_first_token_and_pads_zero(setup):
    cfg, params = setup
    prompt, mask = _prompt(cfg)
    sc = score(params, cfg, prompt, mask)
    lp = np.asarray(sc["logprobs"])
    valid = np.asarray(sc["valid"])
    # first valid token of each row has no scored prefix
    for i in range(lp.shape[0]):
        first = int(np.argmax(np.asarray(mask)[i]))
        assert not valid[i, first]
        assert lp[i, first] == 0.0
    assert (lp[~valid] == 0.0).all()
