"""Dense vs paged cache identity on the pure-functional paths (§13).

The paged layout gathers K/V through identity block tables back to the exact
logical (unrounded) width the dense cache holds, so every downstream fp op is
the same term-for-term program: tokens AND logprobs must be bit-identical,
not merely close — across generate (non-block-aligned widths), the one-pass
SPEC-RL resume, the §9 drafted decode loop (``pad_cache`` through
``_pad_paged_run``), and MLA latent caches."""
import copy

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import RolloutCache, SpecConfig, rollout
from repro.drafting import DraftConfig, drafted_generate
from repro.engine.generate import GenerateConfig, generate
from repro.models import model as M
from repro.models.config import ModelConfig

B, P, N = 3, 8, 11                # cache_len 19: non-aligned for bs=4


@pytest.fixture(scope="module")
def setup():
    cfg = ModelConfig(name="t", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=32)
    params = M.init_lm(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, P), 3, 32)
    mask = np.ones((B, P), bool)
    mask[0, :3] = False            # mixed prompt lengths
    mask[2, :1] = False
    prompt = jnp.where(jnp.asarray(mask), prompt, 0)
    return cfg, params, prompt, jnp.asarray(mask)


def _paged(cfg, bs=4):
    return cfg.replace(cache_layout="paged", kv_block_size=bs)


def _assert_bitwise(got, want):
    np.testing.assert_array_equal(np.asarray(got["tokens"]),
                                  np.asarray(want["tokens"]))
    np.testing.assert_array_equal(np.asarray(got["length"]),
                                  np.asarray(want["length"]))
    np.testing.assert_array_equal(np.asarray(got["logprobs"]),
                                  np.asarray(want["logprobs"]))


@pytest.mark.parametrize("bs", [4, 8])
def test_generate_identity(setup, bs):
    cfg, params, prompt, mask = setup
    gen = GenerateConfig(max_new_tokens=N, temperature=0.7)
    key = jax.random.PRNGKey(7)
    want = generate(params, cfg, gen, prompt, mask, key)
    got = generate(params, _paged(cfg, bs), gen, prompt, mask, key)
    _assert_bitwise(got, want)


def test_generate_identity_mla(setup):
    """MLA latent caches page the (run, NB, bs, rank) pools the same way."""
    _, _, prompt, mask = setup
    cfg = ModelConfig(name="mla", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=4, d_ff=128, vocab_size=32,
                      attention_kind="mla", q_lora_rank=32, kv_lora_rank=32,
                      qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16)
    params = M.init_lm(jax.random.PRNGKey(2), cfg)
    gen = GenerateConfig(max_new_tokens=N, temperature=0.7)
    key = jax.random.PRNGKey(13)
    want = generate(params, cfg, gen, prompt, mask, key)
    got = generate(params, _paged(cfg), gen, prompt, mask, key)
    _assert_bitwise(got, want)


def test_one_pass_rollout_identity(setup):
    """3 SPEC-RL steps (prefill, then verify→compact→resume reuse): the
    paged cache round-trips through cache_gather compaction and the paged
    slot write, matching dense exactly at every step."""
    cfg, params, prompt, mask = setup
    gen = GenerateConfig(max_new_tokens=N, temperature=0.7)
    spec = SpecConfig(variant="spec", one_pass="on")
    pids = list(range(B))
    outs = {}
    for layout, c in (("dense", cfg), ("paged", _paged(cfg))):
        cache = RolloutCache(history=4)
        outs[layout] = []
        for step in range(3):
            o = rollout(params, c, gen, spec, prompt, mask, pids, cache,
                        jax.random.PRNGKey(100 + step), step)
            outs[layout].append(o)
    reused = 0
    for step, (d, p) in enumerate(zip(outs["dense"], outs["paged"])):
        np.testing.assert_array_equal(p.response, d.response)
        np.testing.assert_array_equal(p.length, d.length)
        np.testing.assert_array_equal(p.behaviour_logprobs,
                                      d.behaviour_logprobs)
        assert p.metrics["n_reused"] == d.metrics["n_reused"]
        reused += int(d.metrics["n_reused"])
    assert reused > 0                     # the resume path actually ran


def test_drafted_generate_identity(setup):
    """§9 drafted decode (multi-token verify writes k+1-wide spans through
    the block table) is greedy-identical to its dense run."""
    cfg, params, prompt, mask = setup
    gen = GenerateConfig(max_new_tokens=N, temperature=0.0)
    key = jax.random.PRNGKey(7)
    draft = DraftConfig(kind="ngram", draft_k=3)
    corpus = None
    want = drafted_generate(params, cfg, gen, prompt, mask, key, draft,
                            corpus=corpus)
    got = drafted_generate(params, _paged(cfg), gen, prompt, mask, key,
                           draft, corpus=corpus)
    _assert_bitwise(got, want)
    assert int(np.asarray(want["length"]).sum()) > 0


def test_drafted_resume_identity(setup):
    """Drafted one-pass resume: ``pad_cache`` grows the paged pool through
    ``_pad_paged_run`` (fresh identity-striped tail blocks) and the
    continuation stays bit-identical to dense."""
    cfg, params, prompt, mask = setup
    gen = GenerateConfig(max_new_tokens=N, temperature=0.7)
    ids = list(range(B))
    spec_d = SpecConfig(variant="spec",
                        draft=DraftConfig(kind="ngram", draft_k=4))
    cache_seed = RolloutCache(history=4)
    rollout(params, cfg, gen, SpecConfig(variant="spec"), prompt, mask, ids,
            cache_seed, jax.random.PRNGKey(0), 0)
    # a different policy for step 1 forces partial rejection: the resume
    # decodes a REAL drafted continuation past the accepted prefix
    params_b = M.init_lm(jax.random.PRNGKey(42), cfg)
    outs = {}
    for layout, c in (("dense", cfg), ("paged", _paged(cfg))):
        cache = copy.deepcopy(cache_seed)
        outs[layout] = rollout(params_b, c, gen, spec_d, prompt, mask, ids,
                               cache, jax.random.PRNGKey(7), 1)
    d, p = outs["dense"], outs["paged"]
    np.testing.assert_array_equal(p.response, d.response)
    np.testing.assert_array_equal(p.length, d.length)
    np.testing.assert_array_equal(p.behaviour_logprobs, d.behaviour_logprobs)
    assert d.metrics["n_reused"] > 0      # partial reuse, real continuation
    assert d.metrics["decode_forwards"] > 0
