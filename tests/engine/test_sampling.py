"""Sampling: temperature/top-p semantics + hypothesis properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.engine.sampling import adjust_logits, entropy_of, logprobs_of, sample


def test_greedy_at_zero_temperature():
    logits = jnp.array([[0.1, 3.0, -1.0], [2.0, 0.0, 1.9]])
    tok, lp = sample(jax.random.PRNGKey(0), logits, temperature=0.0)
    np.testing.assert_array_equal(np.asarray(tok), [1, 0])
    np.testing.assert_array_equal(np.asarray(lp), [0.0, 0.0])


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), top_p=st.floats(0.2, 1.0))
def test_top_p_distribution_valid(seed, top_p):
    logits = jax.random.normal(jax.random.PRNGKey(seed), (3, 16)) * 2
    logp = adjust_logits(logits, 1.0, top_p)
    p = np.asarray(jnp.exp(logp))
    np.testing.assert_allclose(p.sum(-1), 1.0, atol=1e-5)
    # argmax always kept
    am = np.asarray(jnp.argmax(logits, -1))
    assert (p[np.arange(3), am] > 0).all()


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_top_p_keeps_nucleus_mass(seed):
    """Kept tokens form the smallest set with mass >= p."""
    top_p = 0.7
    logits = jax.random.normal(jax.random.PRNGKey(seed), (1, 12)) * 3
    base = np.asarray(jax.nn.softmax(logits, -1))[0]
    kept = np.asarray(jnp.exp(adjust_logits(logits, 1.0, top_p)))[0] > 1e-12
    mass = base[kept].sum()
    assert mass >= top_p - 1e-4
    # removing the smallest kept token drops below p
    if kept.sum() > 1:
        smallest = np.where(kept, base, np.inf).argmin()
        assert mass - base[smallest] < top_p + 1e-6


def test_sampled_logprob_matches_logprobs_of():
    logits = jax.random.normal(jax.random.PRNGKey(1), (64, 20)) * 2
    tok, lp = sample(jax.random.PRNGKey(2), logits, temperature=0.8, top_p=0.9)
    lp2 = logprobs_of(logits, tok, 0.8, 0.9)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lp2), atol=1e-5)


def test_entropy_nonnegative_and_bounded():
    logits = jax.random.normal(jax.random.PRNGKey(3), (8, 32))
    ent = np.asarray(entropy_of(logits))
    assert (ent >= 0).all() and (ent <= np.log(32) + 1e-5).all()


def test_sampling_frequencies_match_distribution():
    """Empirical frequencies track softmax probs (vectorised over draws)."""
    logits = jnp.log(jnp.array([[0.5, 0.3, 0.2]]))
    keys = jax.random.split(jax.random.PRNGKey(4), 4000)
    toks = jax.vmap(lambda k: sample(k, logits)[0][0])(keys)
    freq = np.bincount(np.asarray(toks), minlength=3) / 4000
    np.testing.assert_allclose(freq, [0.5, 0.3, 0.2], atol=0.04)
