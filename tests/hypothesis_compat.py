"""Optional-hypothesis shim: property tests skip cleanly when the library is
absent instead of killing collection for the whole module.

Usage (instead of ``from hypothesis import given, settings, strategies as st``)::

    from hypothesis_compat import given, settings, st

When hypothesis is installed these are the real objects; otherwise ``@given``
becomes a skip marker and ``st.*`` return inert placeholders, so the plain
(non-property) tests in the same module still run.  The tests/ directory is
put on sys.path by tests/conftest.py.
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised when hypothesis is absent
    HAVE_HYPOTHESIS = False

    class _InertStrategies:
        """st.anything(...) -> None; only consumed by the inert ``given``."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _InertStrategies()

    def settings(*a, **k):
        return lambda fn: fn

    def given(*a, **k):
        return pytest.mark.skip(reason="hypothesis not installed")
