"""cache_gather kernel: interpret-mode per-row roll vs the jnp oracle across
shapes (incl. non-tile-aligned), dtypes, and boundary shifts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.cache_gather.ops import cache_roll
from repro.kernels.cache_gather.ref import cache_roll_ref


def _case(R, S, D, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    buf = jax.random.normal(ks[0], (R, S, D))
    shift = jax.random.randint(ks[1], (R,), 0, S + 1).astype(jnp.int32)
    return buf, shift


@pytest.mark.parametrize("R,S,D", [
    (1, 16, 8), (4, 32, 16), (3, 33, 8), (6, 24, 17), (2, 128, 64),
])
def test_interpret_matches_ref(R, S, D):
    buf, shift = _case(R, S, D, seed=R * S + D)
    got = cache_roll(buf, shift, impl="interpret")
    want = cache_roll_ref(buf, shift)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_ref_matches_numpy_roll():
    buf, shift = _case(5, 24, 16, seed=3)
    got = np.asarray(cache_roll(buf, shift, impl="ref"))
    for r in range(5):
        want = np.roll(np.asarray(buf)[r], int(shift[r]), axis=0)
        np.testing.assert_array_equal(got[r], want)


@pytest.mark.parametrize("shift_val", [0, 7, 24])
def test_boundary_shifts(shift_val):
    """shift 0 (identity), mid, and S (full wrap == identity)."""
    buf = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 8))
    shift = jnp.full((2,), shift_val, jnp.int32)
    got = cache_roll(buf, shift, impl="interpret")
    want = cache_roll_ref(buf, shift)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    if shift_val in (0, 24):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(buf))


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32, jnp.int32])
def test_dtypes(dtype):
    buf, shift = _case(3, 32, 16, seed=9)
    buf = buf.astype(dtype)
    got = cache_roll(buf, shift, impl="interpret")
    want = cache_roll_ref(buf, shift)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
