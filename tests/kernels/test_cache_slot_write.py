"""cache_slot_write kernel: interpret-mode batched slot scatter vs the jnp
oracle (bit-exact) across shapes, dtypes, duplicate targets and no-op
admissions, plus the numpy semantics of the public wrapper."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.cache_slot_write.ops import cache_slot_write
from repro.kernels.cache_slot_write.ref import cache_slot_write_ref


def _case(Rd, Rs, S, D, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    dst = jax.random.normal(ks[0], (Rd, S, D))
    src = jax.random.normal(ks[1], (Rs, S, D))
    rows = jax.random.permutation(ks[2], Rd)[:Rs].astype(jnp.int32)
    return dst, src, rows


@pytest.mark.parametrize("Rd,Rs,S,D", [
    (4, 2, 16, 8), (8, 8, 32, 16), (5, 3, 33, 8), (6, 1, 24, 17),
    (3, 2, 128, 64),
])
def test_interpret_matches_ref_bit_exact(Rd, Rs, S, D):
    dst, src, rows = _case(Rd, Rs, S, D, seed=Rd * S + D)
    got = cache_slot_write(dst, src, rows, impl="interpret")
    want = cache_slot_write(dst, src, rows, impl="ref")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_numpy_semantics():
    dst, src, rows = _case(6, 3, 24, 8, seed=1)
    got = np.asarray(cache_slot_write(dst, src, rows, impl="ref"))
    want = np.asarray(dst).copy()
    for i, r in enumerate(np.asarray(rows)):
        want[r] = np.asarray(src)[i]
    np.testing.assert_array_equal(got, want)


def test_duplicate_rows_last_wins():
    dst = jnp.zeros((4, 8, 8))
    src = jnp.stack([jnp.full((8, 8), 1.0), jnp.full((8, 8), 2.0),
                     jnp.full((8, 8), 3.0)])
    rows = jnp.array([2, 2, 0], jnp.int32)
    for impl in ("ref", "interpret"):
        got = np.asarray(cache_slot_write(dst, src, rows, impl=impl))
        assert (got[2] == 2.0).all()          # last duplicate wins
        assert (got[0] == 3.0).all()
        assert (got[1] == 0.0).all() and (got[3] == 0.0).all()


def test_untouched_rows_identical():
    dst, src, rows = _case(8, 2, 16, 8, seed=5)
    got = np.asarray(cache_slot_write(dst, src, rows, impl="interpret"))
    touched = set(np.asarray(rows).tolist())
    for r in range(8):
        if r not in touched:
            np.testing.assert_array_equal(got[r], np.asarray(dst)[r])


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32, jnp.int32])
def test_dtypes(dtype):
    dst, src, rows = _case(5, 2, 32, 16, seed=9)
    dst, src = dst.astype(dtype), src.astype(dtype)
    # rows is duplicate-free here, so the inverse map is a plain scatter
    inv = jnp.full((5,), -1, jnp.int32).at[rows].set(
        jnp.arange(2, dtype=jnp.int32))
    want = cache_slot_write_ref(dst, src, inv)
    for impl in ("ref", "interpret"):
        got = cache_slot_write(dst, src, rows, impl=impl)
        assert got.dtype == dst.dtype
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
