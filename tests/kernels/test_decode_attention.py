"""decode_attention kernel: interpret-mode split-K sweep vs the jnp oracle
across GQA/MQA ratios, sliding windows and per-row live lengths (empty rows,
rows at S-1, mixed depths), plus agreement with the legacy naive decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import (decode_attention_blocked,
                                                decode_attention_ref)


def _case(B, Hq, Hkv, S, D, Dv=None, seed=0):
    """Decode-shaped inputs with mixed per-row cache depths.

    Row b's cache holds a left-padded context: pad_b slots of -1, then
    positions [0, live_b - pad_b).  lengths[b] = live_b is the row's live
    extent and starts[b] = pad_b its first live slot; slots outside
    [starts, lengths) carry pos = -1 (the cache contract)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (B, Hq, 1, D))
    k = jax.random.normal(ks[1], (B, Hkv, S, D))
    v = jax.random.normal(ks[2], (B, Hkv, S, D if Dv is None else Dv))
    rng = np.random.RandomState(seed)
    lengths = np.zeros(B, np.int32)
    starts = np.zeros(B, np.int32)
    q_pos = np.zeros(B, np.int32)
    kpos = np.full((B, S), -1, np.int32)
    for b in range(B):
        if b == 0:
            live = 0                      # empty cache row
        elif b == 1:
            live = S                      # row at the full cache width
        else:
            live = int(rng.randint(1, S))
        pad = int(rng.randint(0, max(live // 2, 1))) if live else 0
        kpos[b, pad:live] = np.arange(live - pad)
        lengths[b] = live
        starts[b] = pad
        q_pos[b] = live - pad - 1 if live else -1
    return (q, k, v, jnp.asarray(q_pos), jnp.asarray(kpos),
            jnp.asarray(lengths), jnp.asarray(starts))


@pytest.mark.parametrize("B,Hq,Hkv,S,D", [
    (4, 4, 2, 64, 16),          # GQA 2x
    (3, 8, 1, 48, 8),           # MQA
    (3, 4, 4, 33, 16),          # MHA, non-divisible S
    (4, 6, 3, 96, 32),          # GQA 2x, wider
])
@pytest.mark.parametrize("window", [0, 16])
def test_split_k_matches_ref(B, Hq, Hkv, S, D, window):
    q, k, v, q_pos, kpos, lengths, starts = _case(B, Hq, Hkv, S, D, seed=S + D)
    want = decode_attention_ref(q, k, v, q_pos, kpos, lengths, window=window)
    got = decode_attention(q, k, v, q_pos, kpos, lengths, window=window,
                           impl="interpret", block_k=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5,
                               rtol=2e-5)
    blk = decode_attention(q, k, v, q_pos, kpos, lengths, window=window,
                           impl="blocked", block_k=16)
    np.testing.assert_allclose(np.asarray(blk), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


def test_empty_rows_are_exact_zero():
    q, k, v, q_pos, kpos, lengths, starts = _case(4, 4, 2, 40, 16, seed=3)
    for impl in ("naive", "blocked", "interpret"):
        out = np.asarray(decode_attention(q, k, v, q_pos, kpos, lengths,
                                          impl=impl, block_k=16))
        assert (out[0] == 0.0).all(), impl         # lengths[0] == 0


def test_lengths_none_defaults_to_full_width():
    q, k, v, q_pos, kpos, _, _ = _case(3, 4, 2, 40, 16, seed=5)
    want = decode_attention_ref(q, k, v, q_pos, kpos, None)
    for impl in ("blocked", "interpret"):
        got = decode_attention(q, k, v, q_pos, kpos, None, impl=impl,
                               block_k=16)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)


def test_lengths_are_authoritative():
    """A lengths bound tighter than the pos pattern masks the tail — every
    impl agrees, so a wrong (too small) bound can never desynchronise them."""
    B, Hq, Hkv, S, D = 2, 4, 2, 48, 16
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (B, Hq, 1, D))
    k = jax.random.normal(ks[1], (B, Hkv, S, D))
    v = jax.random.normal(ks[2], (B, Hkv, S, D))
    kpos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    q_pos = jnp.full((B,), S - 1, jnp.int32)
    lengths = jnp.array([S, 17], jnp.int32)       # row 1: live slots ignored
    want = decode_attention_ref(q, k, v, q_pos, kpos, lengths)
    full = decode_attention_ref(q, k, v, q_pos, kpos, None)
    assert not np.allclose(np.asarray(want[1]), np.asarray(full[1]))
    for impl in ("blocked", "interpret"):
        got = decode_attention(q, k, v, q_pos, kpos, lengths, impl=impl,
                               block_k=16)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)


def test_mla_shaped_distinct_kv_dims():
    """G = 1 (MHA after MLA decompression) with Dk != Dv."""
    q, k, v, q_pos, kpos, lengths, starts = _case(3, 4, 4, 40, 24, Dv=16, seed=7)
    want = decode_attention_ref(q, k, v, q_pos, kpos, lengths)
    for impl in ("blocked", "interpret"):
        got = decode_attention(q, k, v, q_pos, kpos, lengths, impl=impl,
                               block_k=16)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)


def test_naive_impl_matches_legacy_decode_bitwise():
    """impl='naive' through the op == dot_product_attention at T=1, bit for
    bit: routing decode through the op keeps the legacy path reproducible."""
    from repro.models.attention import dot_product_attention
    q, k, v, q_pos, kpos, lengths, starts = _case(4, 4, 2, 40, 16, seed=11)
    legacy = dot_product_attention(q, k, v, q_pos[:, None], kpos)
    got = decode_attention(q, k, v, q_pos, kpos, lengths, impl="naive")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(legacy))


def test_single_split_degenerate():
    """block_k >= S: one split; the combine stage must be an identity."""
    q, k, v, q_pos, kpos, lengths, starts = _case(3, 4, 2, 24, 16, seed=13)
    want = decode_attention_ref(q, k, v, q_pos, kpos, lengths)
    got = decode_attention(q, k, v, q_pos, kpos, lengths, impl="interpret",
                           block_k=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


@pytest.mark.parametrize("window", [0, 16])
def test_starts_skip_dead_left_padding(window):
    """Per-row start bounds (resume-shaped: dead left pad before the
    compacted context) agree across every impl."""
    q, k, v, q_pos, kpos, lengths, starts = _case(4, 4, 2, 64, 16, seed=19)
    want = decode_attention_ref(q, k, v, q_pos, kpos, lengths, starts,
                                window=window)
    # starts bound == the pos mask it mirrors, so it changes nothing...
    base = decode_attention_ref(q, k, v, q_pos, kpos, lengths, window=window)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(base))
    for impl in ("naive", "blocked", "interpret"):
        got = decode_attention(q, k, v, q_pos, kpos, lengths, starts,
                               window=window, impl=impl, block_k=16)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)


def test_starts_are_authoritative():
    """...but a start bound tighter than the pos pattern masks the head,
    and every impl still agrees (same contract as lengths)."""
    q, k, v, q_pos, kpos, lengths, starts = _case(4, 4, 2, 64, 16, seed=23)
    tight = jnp.minimum(starts + 11, lengths)
    want = decode_attention_ref(q, k, v, q_pos, kpos, lengths, tight)
    base = decode_attention_ref(q, k, v, q_pos, kpos, lengths, starts)
    assert not np.allclose(np.asarray(want), np.asarray(base))
    for impl in ("blocked", "interpret"):
        got = decode_attention(q, k, v, q_pos, kpos, lengths, tight,
                               impl=impl, block_k=16)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)


def test_row_budget_independence():
    """Garbage K/V outside each row's live range never leaks into outputs."""
    q, k, v, q_pos, kpos, lengths, starts = _case(4, 4, 2, 64, 16, seed=17)
    dead = ((jnp.arange(64) >= lengths[:, None])
            | (jnp.arange(64) < starts[:, None]))[:, None, :, None]
    k2 = jnp.where(dead, 999.0, k)
    v2 = jnp.where(dead, -999.0, v)
    for impl in ("blocked", "interpret"):
        a = decode_attention(q, k, v, q_pos, kpos, lengths, starts,
                             impl=impl, block_k=16)
        b = decode_attention(q, k2, v2, q_pos, kpos, lengths, starts,
                             impl=impl, block_k=16)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------- multi-token blocks (§9)


def _block_case(B, Hq, Hkv, S, D, T, Dv=None, seed=0):
    """Draft-verify-shaped inputs: per row, a contiguous live context of
    ctx_b tokens followed by a written block of qlen_b <= T query tokens at
    consecutive positions; block columns t >= qlen_b carry q_pos = -1 and
    their cache slots pos = -1 (draft padding)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (B, Hq, T, D))
    k = jax.random.normal(ks[1], (B, Hkv, S, D))
    v = jax.random.normal(ks[2], (B, Hkv, S, D if Dv is None else Dv))
    rng = np.random.RandomState(seed)
    lengths = np.zeros(B, np.int32)
    starts = np.zeros(B, np.int32)
    q_pos = np.full((B, T), -1, np.int32)
    kpos = np.full((B, S), -1, np.int32)
    for b in range(B):
        ctx = int(rng.randint(1, S - T))
        pad = int(rng.randint(0, ctx))
        if b == 0:
            qlen = 0                      # done row: no live queries
        elif b == 1:
            qlen = T                      # full draft block
        else:
            qlen = int(rng.randint(1, T + 1))
        kpos[b, pad:ctx] = np.arange(ctx - pad)
        kpos[b, ctx:ctx + qlen] = np.arange(ctx - pad, ctx - pad + qlen)
        q_pos[b, :qlen] = np.arange(ctx - pad, ctx - pad + qlen)
        lengths[b] = ctx + T              # block bound incl. padded slots
        starts[b] = pad
    return (q, k, v, jnp.asarray(q_pos), jnp.asarray(kpos),
            jnp.asarray(lengths), jnp.asarray(starts))


@pytest.mark.parametrize("B,Hq,Hkv,S,D,T", [
    (4, 4, 2, 64, 16, 5),       # GQA 2x, draft_k = 4
    (3, 8, 1, 48, 8, 3),        # MQA
    (3, 4, 4, 40, 16, 2),       # MHA
])
@pytest.mark.parametrize("window", [0, 16])
def test_block_query_matches_ref(B, Hq, Hkv, S, D, T, window):
    """T-token blocks: interpret-mode kernel == naive oracle == blocked."""
    q, k, v, q_pos, kpos, lengths, starts = _block_case(B, Hq, Hkv, S, D, T,
                                                        seed=S + D + T)
    want = decode_attention_ref(q, k, v, q_pos, kpos, lengths, starts,
                                window=window)
    for impl in ("blocked", "interpret"):
        got = decode_attention(q, k, v, q_pos, kpos, lengths, starts,
                               window=window, impl=impl, block_k=16)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)


def test_block_query_causal_within_block():
    """Query t must not see block tokens written after it: perturbing slot
    t+1's K/V leaves query t's output bit-unchanged on every impl."""
    B, Hq, Hkv, S, D, T = 2, 4, 2, 48, 16, 4
    q, k, v, q_pos, kpos, lengths, starts = _block_case(
        B, Hq, Hkv, S, D, T, seed=3)
    # poke the LAST block slot of row 1 (qlen == T there by construction)
    last = int(np.asarray(lengths)[1]) - 1
    k2 = k.at[1, :, last].set(123.0)
    v2 = v.at[1, :, last].set(-123.0)
    for impl in ("naive", "blocked", "interpret"):
        a = decode_attention(q, k, v, q_pos, kpos, lengths, starts,
                             impl=impl, block_k=16)
        b2 = decode_attention(q, k2, v2, q_pos, kpos, lengths, starts,
                              impl=impl, block_k=16)
        np.testing.assert_array_equal(np.asarray(a[:, :, :T - 1]),
                                      np.asarray(b2[:, :, :T - 1]))
        assert not np.allclose(np.asarray(a[1, :, T - 1]),
                               np.asarray(b2[1, :, T - 1]))


def test_block_query_mla_shapes():
    """Dk != Dv with a multi-token block (MLA drafting)."""
    q, k, v, q_pos, kpos, lengths, starts = _block_case(
        3, 4, 4, 40, 24, 3, Dv=16, seed=11)
    want = decode_attention_ref(q, k, v, q_pos, kpos, lengths, starts)
    for impl in ("blocked", "interpret"):
        got = decode_attention(q, k, v, q_pos, kpos, lengths, starts,
                               impl=impl, block_k=16)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)


def test_block_query_t1_matches_legacy_shapes():
    """A (B, T=1) position array is the same call as the legacy (B,) one."""
    q, k, v, q_pos, kpos, lengths, starts = _case(4, 4, 2, 64, 16, seed=29)
    for impl in ("naive", "blocked", "interpret"):
        a = decode_attention(q, k, v, q_pos, kpos, lengths, starts,
                             impl=impl, block_k=16)
        b = decode_attention(q, k, v, q_pos[:, None], kpos, lengths, starts,
                             impl=impl, block_k=16)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
