"""flash_attention kernel: interpret-mode sweep vs the jnp oracle across
GQA ratios, windows, padding, dtypes, and non-divisible tile shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref


def _case(B, Hq, Hkv, T, D, seed=0, pad_rows=True):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (B, Hq, T, D))
    k = jax.random.normal(ks[1], (B, Hkv, T, D))
    v = jax.random.normal(ks[2], (B, Hkv, T, D))
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    if pad_rows and B > 1:
        npad = min(T // 3, 5)
        row = jnp.concatenate([jnp.full((npad,), -1, jnp.int32),
                               jnp.arange(T - npad, dtype=jnp.int32)])
        pos = pos.at[0].set(row)
    return q, k, v, pos


@pytest.mark.parametrize("B,Hq,Hkv,T,D", [
    (1, 1, 1, 32, 8), (2, 4, 2, 64, 16), (2, 8, 1, 48, 16),
    (1, 6, 3, 65, 32), (2, 4, 4, 33, 8),
])
@pytest.mark.parametrize("window", [0, 16])
def test_matches_ref(B, Hq, Hkv, T, D, window):
    q, k, v, pos = _case(B, Hq, Hkv, T, D, seed=T + D)
    a = flash_attention(q, k, v, pos, pos, window=window, impl="interpret",
                        block_q=16, block_k=16)
    b = flash_attention_ref(q, k, v, pos, pos, window=window)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5,
                               rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_dtypes(dtype):
    q, k, v, pos = _case(2, 4, 2, 40, 16, seed=7)
    q, k, v = (t.astype(dtype) for t in (q, k, v))
    a = flash_attention(q, k, v, pos, pos, impl="interpret", block_q=16,
                        block_k=16)
    b = flash_attention_ref(q, k, v, pos, pos)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=tol, rtol=tol)


def test_non_causal():
    q, k, v, pos = _case(1, 2, 2, 24, 8, seed=3, pad_rows=False)
    a = flash_attention(q, k, v, pos, pos, causal=False, impl="interpret",
                        block_q=8, block_k=8)
    b = flash_attention_ref(q, k, v, pos, pos, causal=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5,
                               rtol=2e-5)


def test_matches_model_attention(tiny_cfg):
    """Kernel semantics == the model's dot_product_attention."""
    from repro.models.attention import dot_product_attention
    q, k, v, pos = _case(2, 4, 2, 32, 16, seed=11)
    a = flash_attention(q, k, v, pos, pos, impl="interpret", block_q=16,
                        block_k=16)
    b = dot_product_attention(q, k, v, pos, pos)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5,
                               rtol=2e-5)
