"""Paged decode attention + paged gather/write kernels (DESIGN.md §13).

The paged flash kernel (scalar-prefetched block table redirecting K/V tile
DMAs) is checked in interpret mode against the gathered-dense oracle —
``gather_paged_kv`` + the already-tested ``decode_attention`` — across GQA
and MLA-shaped pools, shuffled and shared tables, dead rows, and sink
redirects.  ``paged_gather`` / ``paged_slot_write`` round-trips cover the
re-paging primitives the serving engine admits through.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.cache_gather.ops import paged_gather
from repro.kernels.cache_slot_write.ops import paged_slot_write
from repro.kernels.decode_attention.ops import (decode_attention,
                                                gather_paged_kv,
                                                paged_decode_attention)


def _paged_case(B, Hq, Hkv, S, D, bs, seed=0, share=False):
    """Pool + shuffled table + mixed-depth positions for one decode step.

    Logical row b holds a left-padded context (pad, then [0, live-pad));
    its blocks are scattered through the pool in shuffled order.  With
    ``share`` the LAST row reuses row 0's table — aliased reads, the CoW
    read pattern."""
    rng = np.random.RandomState(seed)
    nb = -(-S // bs)
    NB = 1 + B * nb                       # block 0 = sink
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, Hq, 1, D))
    k_pool = jax.random.normal(ks[1], (NB, Hkv, bs, D))
    v_pool = jax.random.normal(ks[2], (NB, Hkv, bs, D))
    perm = rng.permutation(NB - 1) + 1    # never the sink
    table = perm[:B * nb].reshape(B, nb).astype(np.int32)
    if share:
        table[B - 1] = table[2]
    lengths = np.zeros(B, np.int32)
    starts = np.zeros(B, np.int32)
    q_pos = np.zeros(B, np.int32)
    kpos = np.full((B, S), -1, np.int32)
    for b in range(B):
        live = 0 if b == 0 else (S if b == 1 else int(rng.randint(1, S)))
        pad = int(rng.randint(0, max(live // 2, 1))) if live else 0
        kpos[b, pad:live] = np.arange(live - pad)
        lengths[b], starts[b] = live, pad
        q_pos[b] = live - pad - 1 if live else -1
    if share:
        kpos[B - 1] = kpos[2]
        lengths[B - 1], starts[B - 1] = lengths[2], starts[2]
        q_pos[B - 1] = q_pos[2]
    return (q, k_pool, v_pool, jnp.asarray(table), jnp.asarray(q_pos),
            jnp.asarray(kpos), jnp.asarray(lengths), jnp.asarray(starts))


@pytest.mark.parametrize("B,Hq,Hkv,S,D,bs", [
    (4, 4, 2, 64, 16, 16),        # GQA 2x, aligned
    (3, 8, 1, 48, 8, 16),         # MQA
    (4, 4, 4, 33, 16, 8),         # MHA, non-block-aligned logical width
    (3, 6, 3, 40, 32, 8),         # GQA 2x
])
@pytest.mark.parametrize("window", [0, 16])
def test_paged_kernel_matches_gathered_dense(B, Hq, Hkv, S, D, bs, window):
    q, kp, vp, table, q_pos, kpos, lengths, starts = _paged_case(
        B, Hq, Hkv, S, D, bs, seed=S + D + bs)
    Sr = table.shape[1] * bs              # block-rounded physical width
    kd = gather_paged_kv(kp, table)
    vd = gather_paged_kv(vp, table)
    kpos_r = jnp.pad(kpos, ((0, 0), (0, Sr - S)), constant_values=-1)
    want = decode_attention(q, kd, vd, q_pos, kpos_r, lengths, starts=starts,
                            window=window, impl="naive")
    got = paged_decode_attention(q, kp, vp, table, q_pos, kpos, lengths,
                                 starts=starts, window=window,
                                 impl="interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5,
                               rtol=2e-5)
    # the gather-and-defer fallback is the same oracle by construction
    blk = paged_decode_attention(q, kp, vp, table, q_pos, kpos, lengths,
                                 starts=starts, window=window, impl="blocked")
    np.testing.assert_allclose(np.asarray(blk), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


def test_paged_kernel_shared_and_sink_blocks():
    """Aliased tables (two rows reading the same physical blocks — the CoW
    sharing read pattern) and sink-redirected rows (freed slots) both
    match the gathered oracle; the empty row attends to nothing."""
    B, Hq, Hkv, S, D, bs = 5, 4, 2, 32, 16, 8
    q, kp, vp, table, q_pos, kpos, lengths, starts = _paged_case(
        B, Hq, Hkv, S, D, bs, seed=3, share=True)
    # row 0 is empty (length 0): point its table at the sink like a freed
    # serving slot — attention must not read through it
    table = table.at[0].set(0)
    kd = gather_paged_kv(kp, table)
    vd = gather_paged_kv(vp, table)
    want = decode_attention(q, kd, vd, q_pos, kpos, lengths, starts=starts,
                            impl="naive")
    got = paged_decode_attention(q, kp, vp, table, q_pos, kpos, lengths,
                                 starts=starts, impl="interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5,
                               rtol=2e-5)
    # identical queries through aliased tables see identical contexts
    qs = q.at[B - 1].set(q[2])
    alias = paged_decode_attention(qs, kp, vp, table, q_pos, kpos, lengths,
                                   starts=starts, impl="interpret")
    np.testing.assert_array_equal(np.asarray(alias[B - 1]),
                                  np.asarray(alias[2]))
    assert bool(jnp.all(jnp.isfinite(got)))


def test_paged_gather_matches_take():
    rng = np.random.RandomState(0)
    NB, X, D, R, nb = 13, 6, 16, 4, 3
    pool = jnp.asarray(rng.randn(NB, X, D).astype(np.float32))
    table = jnp.asarray(rng.randint(0, NB, size=(R, nb)).astype(np.int32))
    want = jnp.take(pool, table.reshape(-1), axis=0).reshape(R, nb, X, D)
    got = paged_gather(pool, table, impl="interpret")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    ref = paged_gather(pool, table, impl="ref")
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(want))


@pytest.mark.parametrize("gqa", [True, False])
def test_paged_slot_write_roundtrip(gqa):
    """Dense rows cut into blocks and scattered through their tables, then
    gathered back: the round trip is the identity on the written rows and
    every other pool block is untouched."""
    rng = np.random.RandomState(1)
    run, NB, Hkv, bs, D, R, nb = 2, 11, 2, 4, 8, 3, 2
    shape = (run, NB, Hkv, bs, D) if gqa else (run, NB, bs, D)
    pool = jnp.asarray(rng.randn(*shape).astype(np.float32))
    src_shape = (run, R, Hkv, nb * bs, D) if gqa else (run, R, nb * bs, D)
    src = jnp.asarray(rng.randn(*src_shape).astype(np.float32))
    # disjoint non-sink blocks per row
    blocks = rng.permutation(NB - 1)[:R * nb] + 1
    tables = jnp.asarray(
        np.broadcast_to(blocks.reshape(R, nb), (run, R, nb)).astype(np.int32))
    out = paged_slot_write(pool, src, tables, impl="interpret")
    flat = np.asarray(out)
    for r in range(R):
        got = np.take(np.asarray(out)[0], np.asarray(tables)[0, r], axis=0)
        if gqa:
            want = np.asarray(src)[0, r].reshape(Hkv, nb, bs, D) \
                .transpose(1, 0, 2, 3)
        else:
            want = np.asarray(src)[0, r].reshape(nb, bs, D)
        np.testing.assert_array_equal(got, want)
    untouched = sorted(set(range(NB)) - set(blocks.tolist()))
    np.testing.assert_array_equal(flat[:, untouched],
                                  np.asarray(pool)[:, untouched])
