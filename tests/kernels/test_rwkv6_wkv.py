"""rwkv6_wkv kernel: interpret-mode sweep vs the lax.scan oracle + state
handoff (chunked processing must equal one shot — the decode-cache
contract)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.rwkv6_wkv.ops import wkv
from repro.kernels.rwkv6_wkv.ref import wkv_ref


def _case(B, T, H, hd, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    r = jax.random.normal(ks[0], (B, T, H, hd))
    k = jax.random.normal(ks[1], (B, T, H, hd))
    v = jax.random.normal(ks[2], (B, T, H, hd))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, hd)))
    u = jax.random.normal(ks[4], (H, hd)) * 0.3
    s0 = jax.random.normal(ks[5], (B, H, hd, hd)) * 0.1
    return r, k, v, w, u, s0


@pytest.mark.parametrize("B,T,H,hd,bt", [
    (1, 8, 1, 4, 4), (2, 37, 3, 8, 16), (1, 64, 2, 16, 32), (3, 16, 4, 8, 8),
])
def test_matches_ref(B, T, H, hd, bt):
    r, k, v, w, u, s0 = _case(B, T, H, hd, seed=B * T + hd)
    ya, sa = wkv(r, k, v, w, u, s0, impl="interpret", block_t=bt)
    yb, sb = wkv(r, k, v, w, u, s0, impl="ref")
    np.testing.assert_allclose(np.asarray(ya), np.asarray(yb), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(sa), np.asarray(sb), atol=1e-4,
                               rtol=1e-4)


def test_state_handoff():
    """Running [0:T1] then [T1:T] from the carried state == one shot."""
    B, T, H, hd = 2, 24, 2, 8
    r, k, v, w, u, s0 = _case(B, T, H, hd, seed=5)
    y_full, s_full = wkv(r, k, v, w, u, s0, impl="ref")
    T1 = 10
    y1, s1 = wkv(r[:, :T1], k[:, :T1], v[:, :T1], w[:, :T1], u, s0, impl="ref")
    y2, s2 = wkv(r[:, T1:], k[:, T1:], v[:, T1:], w[:, T1:], u, s1, impl="ref")
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full), atol=1e-4,
                               rtol=1e-4)


def test_pad_tokens_leave_state_unchanged():
    """w=1, k=0 at a position => state passes through (padding contract)."""
    B, T, H, hd = 1, 6, 1, 4
    r, k, v, w, u, s0 = _case(B, T, H, hd, seed=9)
    k = k.at[:, 3].set(0.0)
    w = w.at[:, 3].set(1.0)
    _, s_a = wkv(r, k, v, w, u, s0, impl="ref")
    # remove position 3 entirely
    keep = [0, 1, 2, 4, 5]
    _, s_b = wkv(r[:, keep], k[:, keep], v[:, keep], w[:, keep], u, s0,
                 impl="ref")
    np.testing.assert_allclose(np.asarray(s_a), np.asarray(s_b), atol=1e-5)
