"""spec_verify kernel: interpret-mode sweep vs the pure-jnp oracle +
hypothesis properties on the verification rule itself."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.kernels.spec_verify.kernel import spec_verify_pallas
from repro.kernels.spec_verify.ops import spec_verify
from repro.kernels.spec_verify.ref import spec_verify_ref


def _case(B, T, seed=0):
    k = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(k, 4)
    lp_curr = jax.random.normal(k1, (B, T)) * 0.7 - 1.5
    lp_prev = jax.random.normal(k2, (B, T)) * 0.7 - 1.5
    u = jax.random.uniform(k3, (B, T))
    vl = jax.random.randint(k4, (B,), 0, T + 1).astype(jnp.int32)
    return lp_curr, lp_prev, u, vl


@pytest.mark.parametrize("B,T,bb,bt", [
    (1, 16, 1, 16), (3, 100, 2, 32), (8, 512, 8, 128),
    (5, 700, 4, 256), (16, 33, 16, 8),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("log_l", [-1.0, 0.0, 0.5])
def test_kernel_matches_ref(B, T, bb, bt, dtype, log_l):
    lp_curr, lp_prev, u, vl = _case(B, T, seed=B * T)
    lp_curr, lp_prev = lp_curr.astype(dtype), lp_prev.astype(dtype)
    got = spec_verify(lp_curr, lp_prev, u, vl, log_l, impl="interpret",
                      block_b=bb, block_t=bt)
    want = spec_verify_ref(lp_curr.astype(jnp.float32),
                           lp_prev.astype(jnp.float32), u, vl, log_l)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_limits():
    lp_curr, lp_prev, u, _ = _case(4, 64)
    vl = jnp.full((4,), 64, jnp.int32)
    # l -> inf: accept everything
    n = spec_verify_ref(lp_curr, lp_prev, u, vl, 1e9)
    assert (n == 64).all()
    # l -> 0: reject at position 0
    n = spec_verify_ref(lp_curr, lp_prev, u, vl, -1e9)
    assert (n == 0).all()
    # identical policies, l>=1: accept everything (Eq. 3)
    n = spec_verify_ref(lp_curr, lp_curr, u, vl, 0.0)
    assert (n == 64).all()


def test_empty_draft():
    lp_curr, lp_prev, u, _ = _case(3, 32)
    vl = jnp.zeros((3,), jnp.int32)
    n = spec_verify(lp_curr, lp_prev, u, vl, 0.5, impl="interpret",
                    block_b=2, block_t=16)
    assert (np.asarray(n) == 0).all()


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000),
       l1=st.floats(-2.0, 2.0), l2=st.floats(-2.0, 2.0))
def test_monotone_in_lenience(seed, l1, l2):
    """Shared randomness: larger lenience never shortens the prefix."""
    lp_curr, lp_prev, u, vl = _case(4, 48, seed=seed)
    lo, hi = min(l1, l2), max(l1, l2)
    n_lo = np.asarray(spec_verify_ref(lp_curr, lp_prev, u, vl, lo))
    n_hi = np.asarray(spec_verify_ref(lp_curr, lp_prev, u, vl, hi))
    assert (n_hi >= n_lo).all()


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_n_in_range(seed):
    lp_curr, lp_prev, u, vl = _case(6, 40, seed=seed)
    n = np.asarray(spec_verify_ref(lp_curr, lp_prev, u, vl, 0.3))
    assert (n >= 0).all() and (n <= np.asarray(vl)).all()
