"""checkpoint/io: atomic pytree writes, the ``latest`` commit pointer, and
the lossless RolloutCache round-trip (entries, LRU recency, sibling groups,
eviction bound, counters) that §10 recovery builds on."""
import glob
import os

import numpy as np
import pytest

from repro.checkpoint.io import (load_pytree, load_rollout_cache, read_latest,
                                 save_pytree, save_rollout_cache,
                                 write_latest)
from repro.core.cache import RolloutCache


def _no_tmp_files(d):
    return not glob.glob(os.path.join(str(d), "**", "*.tmp"), recursive=True)


def test_pytree_roundtrip_and_atomicity(tmp_path):
    tree = {
        "a": np.arange(6, dtype=np.int32).reshape(2, 3),
        "nested": {"b": np.float32(1.5),
                   "seq": [np.ones(2), np.zeros(3)],
                   "tup": (np.int64(7),)},
    }
    p = str(tmp_path / "ck")
    save_pytree(p, tree, metadata={"step": 3})
    out, meta = load_pytree(p)
    assert meta["step"] == 3
    np.testing.assert_array_equal(np.asarray(out["a"]), tree["a"])
    np.testing.assert_array_equal(np.asarray(out["nested"]["seq"][1]),
                                  np.zeros(3))
    assert isinstance(out["nested"]["tup"], tuple)
    # temp names never survive a completed save — a crash mid-write leaves
    # either the old file or a .tmp that loaders never open
    assert _no_tmp_files(tmp_path)


def test_latest_pointer_is_the_commit_point(tmp_path):
    d = str(tmp_path / "ckpts")
    assert read_latest(d) is None
    save_pytree(os.path.join(d, "step_1"), {"x": np.ones(2)})
    assert read_latest(d) is None               # on disk but not committed
    write_latest(d, "step_1")
    assert read_latest(d) == "step_1"
    save_pytree(os.path.join(d, "step_2"), {"x": np.ones(2)})
    write_latest(d, "step_2")                   # pointer flip is atomic
    assert read_latest(d) == "step_2"
    assert _no_tmp_files(tmp_path)


def test_read_latest_rejects_dangling_pointer(tmp_path):
    # §12 hardening: a crash between "pointer flipped" and "files durable"
    # (or a hand-rolled pointer) can leave ``latest`` naming a checkpoint
    # with no files on disk — readers must see "no checkpoint", not a name
    # that raises FileNotFoundError downstream.
    d = str(tmp_path / "ckpts")
    write_latest(d, "ghost")
    assert read_latest(d) is None
    save_pytree(os.path.join(d, "real"), {"x": np.zeros(1)})
    write_latest(d, "real")
    assert read_latest(d) == "real"


def _seeded_cache():
    rng = np.random.RandomState(0)
    cache = RolloutCache(history=2, max_prompts=4, group_size=2)
    for pid in range(6):                        # 6 puts into a 4-prompt bound
        for step in range(2):
            L = int(rng.randint(2, 8))
            cache.put(pid, rng.randint(0, 32, L).astype(np.int32),
                      rng.randn(L).astype(np.float32), L, step=step,
                      eos_id=31)
    cache.get(4)                                # LRU touch reorders recency
    cache.get(99)                               # a miss, for the counter
    return cache


def test_rollout_cache_roundtrip_lossless(tmp_path):
    cache = _seeded_cache()
    p = str(tmp_path / "rc")
    save_rollout_cache(p, cache)
    out = load_rollout_cache(p)

    # store: same pids, same LRU order, same entries bit-for-bit
    assert list(out._store) == list(cache._store)
    for pid in cache._store:
        a, b = cache._store[pid], out._store[pid]
        assert len(a) == len(b) and b.maxlen == cache.history
        for ea, eb in zip(a, b):
            np.testing.assert_array_equal(ea.tokens, eb.tokens)
            np.testing.assert_array_equal(ea.logprobs, eb.logprobs)
            assert ea.step == eb.step and ea.ends_with_eos == eb.ends_with_eos
    # sibling groups (evicted members unregistered) and bounds
    assert out._groups == cache._groups and out._group_of == cache._group_of
    assert out.max_prompts == cache.max_prompts
    assert out.group_size == cache.group_size
    for pid in out._store:
        got = [e.tokens.tolist() for e in out.siblings(pid)]
        want = [e.tokens.tolist() for e in cache.siblings(pid)]
        assert got == want
    # counters: restoring must not re-count (loading is not putting)
    for k in ("puts", "hits", "misses", "evictions"):
        assert getattr(out, k) == getattr(cache, k), k
    assert out.evictions == 2


def test_restored_cache_evicts_like_the_original(tmp_path):
    """Same LRU pressure after restore: the next eviction picks the same
    victim in both the original and the round-tripped cache."""
    cache = _seeded_cache()
    p = str(tmp_path / "rc2")
    save_rollout_cache(p, cache)
    out = load_rollout_cache(p)
    tok = np.arange(3, dtype=np.int32)
    lp = np.zeros(3, np.float32)
    cache.put(77, tok, lp, 3, step=9)
    out.put(77, tok, lp, 3, step=9)
    assert list(out._store) == list(cache._store)
    assert out.evictions == cache.evictions == 3


def test_empty_cache_roundtrip(tmp_path):
    p = str(tmp_path / "rc3")
    save_rollout_cache(p, RolloutCache(history=3))
    out = load_rollout_cache(p)
    assert len(out) == 0 and out.history == 3 and out.max_prompts is None
    assert out.get(0) is None                   # miss, not crash


@pytest.mark.parametrize("entries", [0, 5])
def test_roundtrip_then_roundtrip_is_stable(tmp_path, entries):
    """save(load(save(c))) == save(c): serialization is a fixed point."""
    cache = RolloutCache(history=2, group_size=2)
    for pid in range(entries):
        cache.put(pid, np.arange(4, dtype=np.int32),
                  np.zeros(4, np.float32), 4, step=1)
    p1, p2 = str(tmp_path / "x"), str(tmp_path / "y")
    save_rollout_cache(p1, cache)
    save_rollout_cache(p2, load_rollout_cache(p1))
    with open(p1 + ".cache.json") as f1, open(p2 + ".cache.json") as f2:
        assert f1.read() == f2.read()
