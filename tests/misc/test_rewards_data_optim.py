"""Rewards, tokenizer, dataset, AdamW, checkpoint."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.data.dataset import PromptDataset
from repro.data.tokenizer import EOS_ID, PAD_ID, VOCAB_SIZE, decode, encode
from repro.optim import adamw
from repro.rewards.mathgen import MathTaskConfig, generate_problems
from repro.rewards.verifier import batch_rewards, extract_answer, verify_text


# ------------------------------------------------------------------ tokenizer


@settings(max_examples=50, deadline=None)
@given(st.text(alphabet="0123456789+-*/=(). abcxyz", max_size=40))
def test_tokenizer_roundtrip(s):
    assert decode(encode(s)) == s.lower()


def test_special_ids_stable():
    ids = encode("12", add_eos=True)
    assert ids[0] == 1 and ids[-1] == EOS_ID
    assert PAD_ID == 0
    assert max(ids) < VOCAB_SIZE


# ------------------------------------------------------------------ verifier


def test_extract_answer():
    assert extract_answer("the answer is 42") == 42
    assert extract_answer("12+3=15") == 15
    assert extract_answer("-7") == -7
    assert extract_answer("no digits") is None


def test_verify_text():
    assert verify_text("3+4=7", 7) == 1.0
    assert verify_text("3+4=8", 7) == 0.0
    assert verify_text("", 7) == 0.0


@settings(max_examples=30, deadline=None)
@given(st.integers(-999, 999))
def test_verifier_accepts_own_encoding(n):
    toks = encode(str(n), add_eos=True)
    from repro.rewards.verifier import verify_tokens
    assert verify_tokens(toks, n) == 1.0


def test_batch_rewards():
    toks = np.zeros((2, 8), np.int32)
    row0 = encode("7", add_bos=False, add_eos=True)
    toks[0, :len(row0)] = row0
    lens = np.array([len(row0), 0])
    r = batch_rewards(toks, lens, [7, 3])
    np.testing.assert_allclose(r, [1.0, 0.0])


# ------------------------------------------------------------------ dataset


def test_dataset_group_expansion_and_keys():
    problems = generate_problems(MathTaskConfig(num_problems=4))
    ds = PromptDataset(problems, max_prompt_len=12)
    batches = list(ds.epochs(prompts_per_batch=2, group_size=3, num_epochs=2))
    assert len(batches) == 4
    b = batches[0]
    assert b.tokens.shape == (6, 12)
    # same prompt repeated with distinct cache keys
    assert b.cache_keys[0] != b.cache_keys[1]
    assert b.answers[0] == b.answers[1] == b.answers[2]
    # keys stable across epochs for the same problem
    all_keys = set()
    for bb in batches[:2]:
        all_keys.update(bb.cache_keys)
    epoch2_keys = set()
    for bb in batches[2:]:
        epoch2_keys.update(bb.cache_keys)
    assert all_keys == epoch2_keys


def test_left_padding_layout():
    problems = generate_problems(MathTaskConfig(num_problems=2))
    ds = PromptDataset(problems, max_prompt_len=16)
    b = ds.sample_batch(__import__("random").Random(0), 2, 1)
    for i in range(b.tokens.shape[0]):
        m = b.mask[i]
        # contiguous True suffix
        first = int(np.argmax(m))
        assert m[first:].all() and not m[:first].any()


# ------------------------------------------------------------------ adamw


def test_adamw_matches_manual_step():
    cfg = adamw.AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8,
                            weight_decay=0.0, clip_norm=1e9)
    p = {"w": jnp.array([1.0, -2.0])}
    g = {"w": jnp.array([0.5, 0.5])}
    st_ = adamw.init(p)
    new_p, st2, info = adamw.update(cfg, p, g, st_)
    m = 0.1 * 0.5
    v = 0.01 * 0.25
    mh, vh = m / 0.1, v / 0.01
    want = np.array([1.0, -2.0]) - 0.1 * mh / (np.sqrt(vh) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, atol=1e-5)


def test_adamw_weight_decay_pulls_to_zero():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.5)
    p = {"w": jnp.array([10.0])}
    g = {"w": jnp.array([0.0])}
    new_p, *_ = adamw.update(cfg, p, g, adamw.init(p))
    assert float(new_p["w"][0]) < 10.0


def test_grad_clip():
    cfg = adamw.AdamWConfig(lr=1.0, clip_norm=1.0, weight_decay=0.0)
    p = {"w": jnp.zeros((2,))}
    g = {"w": jnp.array([30.0, 40.0])}   # norm 50 -> scaled by 1/50
    _, _, info = adamw.update(cfg, p, g, adamw.init(p))
    assert float(info["grad_norm"]) == pytest.approx(50.0)


def test_lr_schedules():
    c = adamw.AdamWConfig(lr=1.0, schedule="cosine", total_steps=100)
    assert float(adamw.lr_at(c, 0)) == pytest.approx(1.0)
    assert float(adamw.lr_at(c, 100)) == pytest.approx(0.0, abs=1e-6)
    w = adamw.AdamWConfig(lr=1.0, schedule="warmup_cosine", total_steps=100,
                          warmup_steps=10)
    assert float(adamw.lr_at(w, 5)) == pytest.approx(0.5, abs=0.06)


# ------------------------------------------------------------------ checkpoint


def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint.io import load_pytree, save_pytree
    tree = {"a": jnp.arange(4.0), "b": [jnp.ones((2, 2)),
                                        {"c": jnp.array(3)}],
            "t": (jnp.zeros(1), jnp.ones(2))}
    path = str(tmp_path / "ckpt")
    save_pytree(path, tree, {"step": 7})
    loaded, meta = load_pytree(path)
    assert meta["step"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(loaded)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))
    assert isinstance(loaded["t"], tuple)


def test_rollout_cache_roundtrip(tmp_path):
    from repro.checkpoint.io import load_rollout_cache, save_rollout_cache
    from repro.core.cache import RolloutCache
    c = RolloutCache(history=3)
    c.put(5, np.array([1, 2, 2], np.int32), np.array([-1., -2., -3.],
                                                     np.float32), 3, step=9)
    c.put(5, np.array([4], np.int32), np.zeros(1, np.float32), 1, step=10)
    path = str(tmp_path / "c")
    save_rollout_cache(path, c)
    c2 = load_rollout_cache(path)
    assert c2.get(5).step == 10
    assert c2.get(5, lag=2).step == 9
    np.testing.assert_array_equal(c2.get(5, lag=2).tokens, [1, 2, 2])
