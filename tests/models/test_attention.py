"""Attention semantics: GQA grouping, sliding window, qk-norm/bias, MLA."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (apply_gqa, dot_product_attention,
                                    make_gqa, make_mla, apply_mla)
from repro.models.config import ModelConfig


def _pos(B, T):
    return jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))


def test_gqa_equals_repeated_mha():
    """GQA(kv=2) == MHA with kv heads physically repeated."""
    B, Hq, Hkv, T, D = 2, 4, 2, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Hq, T, D))
    k = jax.random.normal(ks[1], (B, Hkv, T, D))
    v = jax.random.normal(ks[2], (B, Hkv, T, D))
    pos = _pos(B, T)
    out_gqa = dot_product_attention(q, k, v, pos, pos)
    k_rep = jnp.repeat(k, Hq // Hkv, axis=1)
    v_rep = jnp.repeat(v, Hq // Hkv, axis=1)
    out_mha = dot_product_attention(q, k_rep, v_rep, pos, pos)
    np.testing.assert_allclose(np.asarray(out_gqa), np.asarray(out_mha),
                               atol=1e-5)


def test_sliding_window_masks_old_tokens():
    """A key outside the window must not influence the output."""
    B, H, T, D = 1, 1, 12, 4
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, H, T, D))
    k = jax.random.normal(ks[1], (B, H, T, D))
    v = jax.random.normal(ks[2], (B, H, T, D))
    pos = _pos(B, T)
    W = 4
    out = dot_product_attention(q, k, v, pos, pos, window=W)
    # perturb key/value at position 0: outputs at t >= W must be unchanged
    k2 = k.at[:, :, 0].add(100.0)
    v2 = v.at[:, :, 0].add(100.0)
    out2 = dot_product_attention(q, k2, v2, pos, pos, window=W)
    np.testing.assert_allclose(np.asarray(out[:, :, W:]),
                               np.asarray(out2[:, :, W:]), atol=1e-5)
    assert not np.allclose(np.asarray(out[:, :, :W]),
                           np.asarray(out2[:, :, :W]))


def test_padding_rows_ignored():
    """Keys at position -1 never contribute."""
    B, H, T, D = 1, 2, 10, 4
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, H, T, D))
    k = jax.random.normal(ks[1], (B, H, T, D))
    v = jax.random.normal(ks[2], (B, H, T, D))
    pos = _pos(B, T)
    pos_padded = pos.at[:, :3].set(-1)
    out_a = dot_product_attention(q, k, v, pos_padded, pos_padded)
    k2 = k.at[:, :, :3].set(999.0)
    v2 = v.at[:, :, :3].set(-999.0)
    out_b = dot_product_attention(q, k2, v2, pos_padded, pos_padded)
    np.testing.assert_allclose(np.asarray(out_a[:, :, 3:]),
                               np.asarray(out_b[:, :, 3:]), atol=1e-4)


def test_causality():
    """Future keys never influence current outputs."""
    B, H, T, D = 1, 1, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, H, T, D))
    k = jax.random.normal(ks[1], (B, H, T, D))
    v = jax.random.normal(ks[2], (B, H, T, D))
    pos = _pos(B, T)
    out = dot_product_attention(q, k, v, pos, pos)
    k2 = k.at[:, :, -1].add(50.0)
    out2 = dot_product_attention(q, k2, v, pos, pos)
    np.testing.assert_allclose(np.asarray(out[:, :, :-1]),
                               np.asarray(out2[:, :, :-1]), atol=1e-5)


def test_mla_cache_decompression_matches_full(tiny_cfg):
    """MLA with latent cache == MLA recomputed from scratch."""
    cfg = tiny_cfg.replace(attention_kind="mla", q_lora_rank=32,
                           kv_lora_rank=32, qk_nope_head_dim=16,
                           qk_rope_head_dim=8, v_head_dim=16)
    p = make_mla(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, T = 2, 12
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model))
    pos = _pos(B, T)
    full, _ = apply_mla(p, cfg, x, pos)
    from repro.models.attention import init_kv_cache
    cache = init_kv_cache(cfg, B, T, jnp.float32)
    via_cache, _ = apply_mla(p, cfg, x, pos, cache=cache, cache_start=0)
    np.testing.assert_allclose(np.asarray(full), np.asarray(via_cache),
                               atol=1e-5)


def test_qkv_bias_changes_output(tiny_cfg):
    cfg_nb = tiny_cfg
    cfg_b = tiny_cfg.replace(qkv_bias=True)
    p = make_gqa(jax.random.PRNGKey(0), cfg_b, jnp.float32)
    assert "bias" in p["wq"]
    p["wq"]["bias"] = p["wq"]["bias"] + 1.0
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg_b.d_model))
    pos = _pos(1, 8)
    out_b, _ = apply_gqa(p, cfg_b, x, pos)
    p0 = {k: (dict(v, bias=jnp.zeros_like(v["bias"])) if isinstance(v, dict)
              and "bias" in v else v) for k, v in p.items()}
    out_0, _ = apply_gqa(p0, cfg_b, x, pos)
    assert not np.allclose(np.asarray(out_b), np.asarray(out_0))
