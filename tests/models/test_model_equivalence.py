"""The engine contract per family: prefill+decode against the cache is
exactly equivalent to a full forward (the property SPEC-RL's correctness
rests on)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import model as M
from repro.models.config import ModelConfig

FAMILIES = {
    "dense-gqa": dict(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                      d_ff=128, vocab_size=97, qk_norm=True, qkv_bias=True),
    "mla": dict(num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
                d_ff=128, vocab_size=97, attention_kind="mla", q_lora_rank=32,
                kv_lora_rank=32, qk_nope_head_dim=16, qk_rope_head_dim=8,
                v_head_dim=16),
    "moe": dict(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                d_ff=128, vocab_size=97, num_experts=4, num_experts_per_tok=2,
                num_shared_experts=1, moe_d_ff=64, first_dense_layers=1),
    "swa": dict(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                d_ff=128, vocab_size=97, sliding_window=8),
    "jamba-like": dict(num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
                       d_ff=128, vocab_size=97, block_kind="mamba",
                       attn_period=4, attn_offset=2, num_experts=4,
                       num_experts_per_tok=2, moe_every=2),
    "rwkv6": dict(num_layers=2, d_model=64, num_heads=0, num_kv_heads=0,
                  d_ff=128, vocab_size=97, block_kind="rwkv",
                  rwkv_head_dim=16),
    "whisper-like": dict(num_layers=2, d_model=64, num_heads=4,
                         num_kv_heads=4, d_ff=128, vocab_size=97,
                         encoder_layers=2, encoder_frames=24,
                         cross_attention=True, pos_embed="learned",
                         max_seq_len=64),
}


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_prefill_decode_equals_forward(family):
    cfg = ModelConfig(name=family, **FAMILIES[family])
    cfg.validate()
    params = M.init_lm(jax.random.PRNGKey(0), cfg)
    B, T = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 3,
                                cfg.vocab_size)
    positions = jnp.stack([
        jnp.concatenate([jnp.full((3,), -1, jnp.int32),
                         jnp.arange(T - 3, dtype=jnp.int32)]),
        jnp.arange(T, dtype=jnp.int32)])
    tokens = jnp.where(positions >= 0, tokens, 0)

    extras = {}
    if cfg.encoder_layers:
        frames = jax.random.normal(jax.random.PRNGKey(2),
                                   (B, cfg.encoder_frames, cfg.d_model))
        enc, epos = M.encode(params, cfg, frames)
        extras = {"encoder_out": enc, "encoder_positions": epos}

    logits, _ = M.forward(params, cfg, tokens, positions, **extras)
    caches = M.init_cache(cfg, B, T + 4)
    plog, caches = M.prefill(params, cfg, tokens, positions, caches, **extras)
    np.testing.assert_allclose(np.asarray(plog), np.asarray(logits),
                               atol=1e-4, rtol=1e-4)

    # two decode steps vs extended forward
    cur_tok = jnp.argmax(logits[:, -1:], axis=-1)
    cur_pos = positions[:, -1:] + 1
    all_tok, all_pos = tokens, positions
    for step in range(2):
        dlog, caches = M.decode_step(params, cfg, cur_tok, cur_pos, caches,
                                     T + step, **extras)
        all_tok = jnp.concatenate([all_tok, cur_tok], axis=1)
        all_pos = jnp.concatenate([all_pos, cur_pos], axis=1)
        flog, _ = M.forward(params, cfg, all_tok, all_pos, **extras)
        np.testing.assert_allclose(np.asarray(dlog[:, 0]),
                                   np.asarray(flog[:, -1]),
                                   atol=1e-4, rtol=1e-4)
        cur_tok = jnp.argmax(dlog, axis=-1)
        cur_pos = cur_pos + 1


def test_mtp_head_shapes():
    cfg = ModelConfig(name="mtp", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=97, mtp=True)
    params = M.init_lm(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 3, 97)
    pos = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32), (2, 8))
    logits, aux = M.forward(params, cfg, tokens, pos, return_mtp=True)
    assert aux["mtp_logits"].shape == logits.shape
    assert not jnp.isnan(aux["mtp_logits"]).any()


def test_param_counts_full_configs():
    """Full production configs have plausible parameter counts (via
    eval_shape — no allocation)."""
    from repro.configs import get_config
    expect = {
        "deepseek-7b": (6e9, 8e9),
        "granite-34b": (30e9, 40e9),
        "qwen3-0.6b": (0.4e9, 0.8e9),
        "mixtral-8x22b": (120e9, 150e9),
        "deepseek-v3-671b": (600e9, 720e9),
        "qwen1.5-110b": (95e9, 125e9),
        "rwkv6-3b": (2.5e9, 4e9),
        "whisper-tiny": (0.02e9, 0.08e9),
        "jamba-v0.1-52b": (45e9, 60e9),
        "pixtral-12b": (11e9, 14e9),
    }
    for arch, (lo, hi) in expect.items():
        cfg = get_config(arch)
        struct = jax.eval_shape(lambda c=cfg: M.init_lm(jax.random.PRNGKey(0), c))
        n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(struct))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params out of range"
