"""MoE: dense vs dispatch consistency, router properties, aux losses."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ModelConfig
from repro.models.moe import _router, apply_moe, make_moe


def _cfg(**kw):
    base = dict(name="m", num_layers=1, d_model=32, num_heads=2,
                num_kv_heads=2, d_ff=64, vocab_size=64, num_experts=4,
                num_experts_per_tok=2, moe_d_ff=48)
    base.update(kw)
    return ModelConfig(**base)


def test_dense_vs_dispatch_no_drop():
    """With generous capacity the GShard dispatch path must equal dense."""
    cfg_d = _cfg(moe_impl="dense")
    cfg_g = _cfg(moe_impl="dispatch", capacity_factor=8.0)
    p = make_moe(jax.random.PRNGKey(0), cfg_d, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 10, 32))
    y_d, aux_d = apply_moe(p, cfg_d, x)
    y_g, aux_g = apply_moe(p, cfg_g, x)
    assert float(aux_g["moe_drop_frac"]) == 0.0
    np.testing.assert_allclose(np.asarray(y_d), np.asarray(y_g), atol=1e-5)


def test_dispatch_drops_under_tight_capacity():
    cfg_g = _cfg(moe_impl="dispatch", capacity_factor=0.25)
    p = make_moe(jax.random.PRNGKey(0), cfg_g, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    _, aux = apply_moe(p, cfg_g, x)
    assert float(aux["moe_drop_frac"]) >= 0.0  # well-defined


def test_router_topk_and_normalised():
    cfg = _cfg()
    p = make_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (30, 32))
    w, idx, aux = _router(p, cfg, x)
    assert w.shape == (30, 2) and idx.shape == (30, 2)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, atol=1e-5)
    assert (np.asarray(idx) >= 0).all() and (np.asarray(idx) < 4).all()
    # each token's two experts are distinct (top_k property)
    assert (np.asarray(idx[:, 0]) != np.asarray(idx[:, 1])).all()


def test_lb_loss_bounds():
    """Load-balance loss >= 1 (=1 iff perfectly uniform routing)."""
    cfg = _cfg()
    p = make_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (200, 32))
    _, _, aux = _router(p, cfg, x)
    assert float(aux["moe_lb_loss"]) >= 0.99
    frac = np.asarray(aux["moe_expert_frac"])
    np.testing.assert_allclose(frac.sum(), 1.0, atol=1e-5)


def test_shared_expert_added():
    cfg = _cfg(num_shared_experts=1)
    p = make_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    assert "shared" in p
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 4, 32))
    y, _ = apply_moe(p, cfg, x)
    p2 = dict(p)
    p2["shared"] = jax.tree.map(jnp.zeros_like, p["shared"])
    y2, _ = apply_moe(p2, cfg, x)
    assert not np.allclose(np.asarray(y), np.asarray(y2))


def test_sort_vs_dense_no_drop():
    """The sort-based dispatch (gather/scatter) must equal dense when no
    token is dropped."""
    cfg_d = _cfg(moe_impl="dense")
    cfg_s = _cfg(moe_impl="sort", capacity_factor=8.0)
    p = make_moe(jax.random.PRNGKey(0), cfg_d, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 12, 32))
    y_d, _ = apply_moe(p, cfg_d, x)
    y_s, aux = apply_moe(p, cfg_s, x)
    assert float(aux["moe_drop_frac"]) == 0.0
    np.testing.assert_allclose(np.asarray(y_d), np.asarray(y_s), atol=1e-5)


def test_dispatch_group_override():
    """moe_groups overrides the per-sequence default and stays exact with
    generous capacity."""
    cfg_d = _cfg(moe_impl="dense")
    cfg_g = _cfg(moe_impl="dispatch", capacity_factor=8.0, moe_groups=4)
    p = make_moe(jax.random.PRNGKey(0), cfg_d, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 12, 32))
    y_d, _ = apply_moe(p, cfg_d, x)
    y_g, aux = apply_moe(p, cfg_g, x)
    assert float(aux["moe_drop_frac"]) == 0.0
    np.testing.assert_allclose(np.asarray(y_d), np.asarray(y_g), atol=1e-5)
