"""Mamba and RWKV6 blocks: cache/state equivalence and padding contracts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ModelConfig
from repro.models.mamba import apply_mamba, init_mamba_cache, make_mamba
from repro.models.rwkv import (apply_rwkv_time_mix, init_rwkv_cache,
                               make_rwkv_time_mix, wkv_scan)


def _pos(B, T):
    return jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))


@pytest.fixture
def mamba_cfg():
    return ModelConfig(name="m", num_layers=1, d_model=32, num_heads=0,
                       num_kv_heads=0, d_ff=64, vocab_size=64,
                       block_kind="mamba", mamba_d_state=8, mamba_d_conv=4)


@pytest.fixture
def rwkv_cfg():
    return ModelConfig(name="r", num_layers=1, d_model=32, num_heads=0,
                       num_kv_heads=0, d_ff=64, vocab_size=64,
                       block_kind="rwkv", rwkv_head_dim=8, rwkv_lora_rank=8)


def test_mamba_full_vs_stepwise(mamba_cfg):
    """Prefill-with-cache then per-token decode == full-sequence forward."""
    cfg = mamba_cfg
    p = make_mamba(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, T = 2, 10
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model))
    pos = _pos(B, T)
    y_full, _ = apply_mamba(p, cfg, x, pos)

    cache = init_mamba_cache(cfg, B, jnp.float32)
    ys = []
    for t in range(T):
        y_t, cache = apply_mamba(p, cfg, x[:, t:t + 1], pos[:, t:t + 1],
                                 cache=cache)
        ys.append(y_t)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_step),
                               atol=1e-4, rtol=1e-4)


def test_mamba_padding_no_state_update(mamba_cfg):
    """Left padding slots leave outputs at valid slots unchanged."""
    cfg = mamba_cfg
    p = make_mamba(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, T, pad = 1, 8, 3
    x = jax.random.normal(jax.random.PRNGKey(2), (B, T, cfg.d_model))
    pos_nopad = _pos(B, T)
    y_ref, _ = apply_mamba(p, cfg, x, pos_nopad)
    # same content shifted right with pad slots in front (zeroed input)
    xp = jnp.concatenate([jnp.zeros((B, pad, cfg.d_model)), x], axis=1)
    posp = jnp.concatenate([jnp.full((B, pad), -1, jnp.int32),
                            pos_nopad], axis=1)
    y_pad, _ = apply_mamba(p, cfg, xp, posp)
    np.testing.assert_allclose(np.asarray(y_pad[:, pad:]), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-4)


def test_rwkv_time_mix_full_vs_stepwise(rwkv_cfg):
    cfg = rwkv_cfg
    p = make_rwkv_time_mix(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, T = 2, 8
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model))
    pos = _pos(B, T)
    y_full, _ = apply_rwkv_time_mix(p, cfg, x, pos)

    cache = init_rwkv_cache(cfg, B, jnp.float32)
    cache = {"shift_t": cache["shift_t"], "wkv": cache["wkv"]}
    ys = []
    for t in range(T):
        y_t, cache = apply_rwkv_time_mix(p, cfg, x[:, t:t + 1],
                                         pos[:, t:t + 1], cache=cache)
        ys.append(y_t)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_step),
                               atol=1e-4, rtol=1e-4)


def test_wkv_scan_decay_zero_forgets():
    """w=0 wipes the state each step: y depends only on the bonus path."""
    B, T, H, hd = 1, 4, 1, 4
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    r = jax.random.normal(ks[0], (B, T, H, hd))
    k = jax.random.normal(ks[1], (B, T, H, hd))
    v = jax.random.normal(ks[2], (B, T, H, hd))
    w = jnp.zeros((B, T, H, hd))
    u = jnp.zeros((H, hd))
    s0 = jnp.zeros((B, H, hd, hd))
    y, s = wkv_scan(r, k, v, w, u, s0)
    # with u=0 and s0=0: y_0 = 0; y_t = r_t @ (k_{t-1}^T v_{t-1})
    np.testing.assert_allclose(np.asarray(y[:, 0]), 0.0, atol=1e-6)
    expect = jnp.einsum("bhk,bhk->bh", r[:, 1].reshape(B, H, hd),
                        k[:, 0].reshape(B, H, hd))[..., None] * \
        v[:, 0].reshape(B, H, hd)
    np.testing.assert_allclose(np.asarray(y[:, 1].reshape(B, H, hd)),
                               np.asarray(expect), atol=1e-5)
