"""§14 alert rules, recompile sentinel, and device/pool gauges."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.obs import MetricsRegistry, Tracer
from repro.obs.alerts import (SEV_CRIT, AlertManager, AlertRule,
                              compile_counts, default_rules, jit_cache_size,
                              record_compile_gauges, record_device_memory,
                              register_jit_entry)

# ------------------------------------------------------------------ rules


def test_threshold_rule_edge_triggered():
    am = AlertManager([AlertRule("low", "x", "below", 0.5)])
    fired = []
    for v in (1.0, 0.4, 0.3, 0.6, 0.2):
        fired.append(len(am.evaluate({"x": v})))
    # fires once entering the bad region, re-arms after clearing, fires again
    assert fired == [0, 1, 0, 0, 1]
    assert am.as_dict()["alerts_fired"] == 2.0


def test_warmup_suppresses_early_samples():
    am = AlertManager([AlertRule("low", "x", "below", 0.5, warmup=3)])
    assert not am.evaluate({"x": 0.0})
    assert not am.evaluate({"x": 0.0})
    assert not am.evaluate({"x": 0.0})
    assert len(am.evaluate({"x": 0.0})) == 1


def test_trend_rule_needs_full_window():
    am = AlertManager([AlertRule("up", "x", "trend_up", 0.0, window=4)])
    events = []
    for v in (1.0, 2.0, 3.0, 4.0):        # monotone rise across the window
        events += am.evaluate({"x": v})
    assert [e.rule for e in events] == ["up"]
    # flat history clears and re-arms
    for v in (4.0, 4.0, 4.0, 4.0):
        events += am.evaluate({"x": v})
    assert len(events) == 1


def test_missing_metric_is_inert():
    am = AlertManager(default_rules())
    for _ in range(20):
        assert am.evaluate({"loss": 1.0}) == []


def test_events_route_to_tracer_and_watchdog():
    class Dog:
        def __init__(self):
            self.got = []

        def note_alert(self, ev):
            self.got.append(ev)

    tr = Tracer(enabled=True)
    dog = Dog()
    am = AlertManager([AlertRule("boom", "x", "above", 0.0,
                                 severity=SEV_CRIT, message="m")],
                      tracer=tr, watchdog=dog)
    evs = am.evaluate({"x": 1.0}, step=7)
    assert len(evs) == 1 and evs[0].step == 7 and evs[0].severity == SEV_CRIT
    assert [e.name for e in tr.events] == ["alert/boom"]
    assert tr.events[0].args["value"] == 1.0
    assert dog.got == evs


def test_trainwatchdog_note_alert_counts(tmp_path):
    from repro.rl.watchdog import TrainWatchdog, WatchdogConfig
    wd = TrainWatchdog(WatchdogConfig(checkpoint_dir=str(tmp_path)))
    am = AlertManager([AlertRule("boom", "x", "above", 0.0,
                                 severity=SEV_CRIT)], watchdog=wd)
    am.evaluate({"x": 1.0})
    assert wd.alert_events == 1 and wd.crit_alert_events == 1
    assert wd.last_alert == "boom"
    assert wd.as_dict()["watchdog_crit_alert_events"] == 1.0


def test_default_rules_fire_on_canned_collapse():
    am = AlertManager(default_rules())
    fired = []
    for step in range(8):
        m = {"accept_rate": 0.5 if step < 6 else 0.01,
             "paged_alloc_failures": 0.0 if step < 7 else 2.0}
        fired += am.evaluate(m, step=step)
    names = {e.rule for e in fired}
    assert names == {"draft_accept_collapse", "pool_alloc_failures"}


# ------------------------------------------------------- recompile sentinel


def test_jit_cache_size_counts_signatures():
    @jax.jit
    def f(x):
        return x + 1

    n0 = jit_cache_size(f)
    if n0 is None:
        pytest.skip("jax build exposes no _cache_size probe")
    f(jnp.zeros(2))
    f(jnp.zeros(2))                       # same signature: no new compile
    assert jit_cache_size(f) == n0 + 1
    f(jnp.zeros(3))                       # new shape: one more
    assert jit_cache_size(f) == n0 + 2


def test_registered_entries_feed_compile_gauges():
    @jax.jit
    def g(x):
        return x * 2

    register_jit_entry("test_entry_g", g)
    try:
        g(jnp.zeros(4))
        counts = compile_counts()
        if "test_entry_g" not in counts:
            pytest.skip("jax build exposes no _cache_size probe")
        assert counts["test_entry_g"] >= 1
        reg = MetricsRegistry()
        record_compile_gauges(reg)
        d = reg.as_dict()
        assert d["compiles.test_entry_g"] >= 1.0
        assert d["compiles.total"] >= d["compiles.test_entry_g"]
    finally:
        from repro.obs.alerts import _JIT_ENTRIES
        _JIT_ENTRIES.pop("test_entry_g", None)


def test_engine_modules_enroll_their_entries():
    import repro.core.verify           # noqa: F401
    import repro.drafting.step         # noqa: F401
    import repro.serving.engine_loop   # noqa: F401
    from repro.obs.alerts import _JIT_ENTRIES
    assert {"draft_step", "verify_drafts", "verify_and_prefill",
            "decode_chunk"} <= set(_JIT_ENTRIES)


def test_recompile_rule_fires_on_cache_growth():
    rules = [r for r in default_rules()
             if r.name == "recompile_steady_state"]
    am = AlertManager(rules)
    evs = []
    # warmup growth ignored, then steady ... then growth again
    for total in (1, 2, 3, 4, 4, 4, 4, 4):
        evs += am.evaluate({"compiles.total": float(total)})
    assert evs == []
    for total in (5, 6, 7, 8):
        evs += am.evaluate({"compiles.total": float(total)})
    assert [e.rule for e in evs] == ["recompile_steady_state"]


# ---------------------------------------------------------------- gauges


def test_record_device_memory_never_raises():
    reg = MetricsRegistry()
    record_device_memory(reg)            # CPU: memory_stats() is None/empty
    d = reg.as_dict()
    for k in d:
        if k.startswith("device."):
            assert np.isfinite(d[k])


def test_paged_pool_gauges_exported():
    from repro.engine.generate import GenerateConfig
    from repro.models import model as M
    from repro.models.config import ModelConfig
    from repro.serving import Request
    from repro.serving.paged_engine import PagedSlotEngine

    cfg = ModelConfig(name="t", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=32,
                      cache_layout="paged", kv_block_size=8)
    params = M.init_lm(jax.random.PRNGKey(0), cfg)
    gen = GenerateConfig(max_new_tokens=4)
    eng = PagedSlotEngine(params, cfg, gen, num_slots=2, prompt_width=8,
                          chunk_steps=2)
    rng = np.random.RandomState(0)
    keys = np.asarray(jax.vmap(lambda i: jax.random.fold_in(
        jax.random.PRNGKey(5), i))(jnp.arange(2)))
    for i in range(2):
        eng.submit(Request(request_id=i,
                           prompt=rng.randint(3, 32, 5).astype(np.int32),
                           key=keys[i], max_new_tokens=4))
    eng.run()
    d = eng.metrics_registry().as_dict()
    assert 0.0 <= d["paged_pool_pressure"] <= 1.0
    assert d["paged_bytes_in_use"] >= 0.0
    assert d["paged_peak_bytes_in_use"] > 0.0
