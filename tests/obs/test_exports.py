"""Export-sink tests (DESIGN.md §11): golden-file Chrome-trace and
Prometheus exposition from a fixed fake-clock scenario, JSONL structure,
and the stdlib /metrics HTTP endpoint.

Regenerate goldens after an intentional format change with:
    REPRO_REGEN_GOLDENS=1 PYTHONPATH=src python -m pytest tests/obs/test_exports.py
"""
import json
import os
import urllib.request

import pytest

from repro.obs import MetricsRegistry, Tracer
from repro.obs.export import (chrome_trace, prometheus_text,
                              start_metrics_server, write_chrome_trace,
                              write_jsonl, write_prometheus)

GOLDEN = os.path.join(os.path.dirname(__file__), "golden")


def _scenario():
    """A fixed request lifecycle + trainer step.  Every timestamp is an
    exact binary fraction so ts * 1e6 is platform-stable in the JSON."""
    eng = Tracer(clock=lambda: 0.0)
    eng.complete("queued", "req/0", 0.0, 0.25, cat="queue", retries=0)
    eng.complete("admit", "req/0", 0.25, 0.3125, cat="admit", slot=0,
                 n_accepted=3)
    eng.complete("decode_chunk", "req/0", 0.3125, 0.5, cat="decode", steps=4)
    eng.event("retry", "req/0", cat="fault", ts=0.5, slot=0)
    eng.complete("decode_chunk", "req/0", 0.5625, 0.75, cat="decode", steps=4)
    eng.complete("request", "req/0", 0.0, 0.78125, cat="lifecycle",
                 reason="complete", tokens=7, retries=1)
    eng.complete("queued", "req/10", 0.0, 0.625, cat="queue", retries=0)
    eng.complete("admit", "engine", 0.25, 0.3125, cat="admit", rows=1)
    eng.complete("decode_chunk", "engine", 0.3125, 0.5, cat="decode",
                 steps=4, busy=1, emitted=4)
    trn = Tracer(clock=lambda: 0.0)
    trn.complete("collect", "trainer", 0.0, 0.8125, cat="train", step=0)
    trn.complete("update_actor", "trainer", 0.8125, 0.875, cat="train",
                 step=0)
    trn.complete("train_step", "trainer", 0.0, 0.875, cat="train", step=0)

    reg = MetricsRegistry()
    reg.inc("serve.generated_tokens", 28)
    reg.inc("serve.reused_tokens", 3)
    reg.inc("serve.busy_slot_steps", 9)
    reg.inc("serve.total_slot_steps", 12)
    reg.set("serve.num_slots", 4.0, agg="sum")
    reg.ratio("serve.occupancy", "serve.busy_slot_steps",
              "serve.total_slot_steps")
    for v in (0.25, 0.5, 0.5, 2.0, 16.0):
        reg.observe("serve.ttft_ms", v)
    reg.observe("serve.reuse_len", 0.0)        # underflow bucket in the wild
    return {"engine": eng, "trainer": trn}, reg


def _check_golden(name, produced):
    path = os.path.join(GOLDEN, name)
    if os.environ.get("REPRO_REGEN_GOLDENS"):
        with open(path, "w") as f:
            f.write(produced)
    with open(path) as f:
        assert produced == f.read()


def test_chrome_trace_matches_golden(tmp_path):
    tracers, _ = _scenario()
    p = tmp_path / "trace.json"
    write_chrome_trace(p, tracers)
    _check_golden("trace.json", p.read_text())


def test_prometheus_matches_golden(tmp_path):
    _, reg = _scenario()
    p = tmp_path / "metrics.prom"
    write_prometheus(p, reg)
    _check_golden("metrics.prom", p.read_text())


def test_chrome_trace_structure():
    tracers, _ = _scenario()
    doc = chrome_trace(tracers)
    evs = doc["traceEvents"]
    # one process per tracer, named
    procs = {e["args"]["name"] for e in evs if e["ph"] == "M"
             and e["name"] == "process_name"}
    assert procs == {"engine", "trainer"}
    # engine lane sorts before request lanes; req/0 before req/10
    names = [e for e in evs if e["ph"] == "M" and e["name"] == "thread_name"
             and e["pid"] == 0]
    lanes = [e["args"]["name"] for e in sorted(names, key=lambda e: e["tid"])]
    assert lanes == ["engine", "req/0", "req/10"]
    # the full lifecycle is on the req/0 lane, in wall-clock order
    tid = {e["args"]["name"]: e["tid"] for e in names}
    req0 = sorted((e for e in evs if e["pid"] == 0 and e["ph"] in "Xi"
                   and e["tid"] == tid["req/0"]), key=lambda e: e["ts"])
    assert [e["name"] for e in req0] == [
        "queued", "request", "admit", "decode_chunk", "retry", "decode_chunk"]
    # X events carry microsecond ts/dur
    q = next(e for e in req0 if e["name"] == "queued")
    assert q["ts"] == 0.0 and q["dur"] == 250000.0
    # instants are thread-scoped
    assert next(e for e in req0 if e["ph"] == "i")["s"] == "t"


def test_prometheus_exposition_shape():
    _, reg = _scenario()
    text = prometheus_text(reg, namespace="repro")
    assert "# TYPE repro_serve_generated_tokens_total counter" in text
    assert "repro_serve_generated_tokens_total 28.0" in text
    assert "# TYPE repro_serve_occupancy gauge" in text
    assert "repro_serve_occupancy 0.75" in text
    # histogram: cumulative buckets, monotonic, ending at +Inf == count
    lines = [ln for ln in text.splitlines()
             if ln.startswith("repro_serve_ttft_ms_bucket")]
    counts = [int(ln.rsplit(" ", 1)[1]) for ln in lines]
    assert counts == sorted(counts)
    assert lines[-1].startswith('repro_serve_ttft_ms_bucket{le="+Inf"}')
    assert counts[-1] == 5
    assert "repro_serve_ttft_ms_count 5" in text
    assert "repro_serve_ttft_ms_sum 19.25" in text


def test_jsonl_records_and_final_metrics(tmp_path):
    tracers, reg = _scenario()
    p = tmp_path / "events.jsonl"
    write_jsonl(p, tracers, reg)
    recs = [json.loads(ln) for ln in p.read_text().splitlines()]
    assert recs[-1]["type"] == "metrics"
    assert recs[-1]["metrics"]["serve.occupancy"] == 0.75
    kinds = {r["type"] for r in recs[:-1]}
    assert kinds == {"span", "event"}
    spans = [r for r in recs if r["type"] == "span"]
    assert all(r["dur"] == r["t1"] - r["t0"] for r in spans)
    # per-process blocks are internally time-ordered
    eng = [r for r in recs[:-1] if r["proc"] == "engine"]
    ts = [r.get("t0", r.get("ts")) for r in eng]
    assert ts == sorted(ts)


def test_metrics_http_endpoint():
    _, reg = _scenario()
    srv = start_metrics_server(lambda: reg, port=0)
    try:
        port = srv.server_address[1]
        with urllib.request.urlopen(
                f"http://127.0.0.1:{port}/metrics", timeout=5) as r:
            assert r.status == 200
            assert "version=0.0.4" in r.headers["Content-Type"]
            body = r.read().decode()
        assert body == prometheus_text(reg)
        # live provider: a scrape after an inc sees the new value
        reg.inc("serve.generated_tokens", 1)
        with urllib.request.urlopen(
                f"http://127.0.0.1:{port}/metrics", timeout=5) as r:
            assert "repro_serve_generated_tokens_total 29.0" in \
                r.read().decode()
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(f"http://127.0.0.1:{port}/nope", timeout=5)
    finally:
        srv.shutdown()
