"""§14 token-provenance ledger: conservation invariant, decision-record
schema round-trip, and savings-attribution arithmetic.

The load-bearing property is CONSERVATION — the category counts of every
finalized row sum exactly to its sequence length, whatever mix of prompt /
reuse / draft / retry events produced it.  It is checked both as a
hypothesis property over random event traces and end-to-end through a real
drafted spec rollout.
"""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.obs import attrib
from repro.obs.ledger import (CATEGORY_NAMES, DECISION_FEATURES,
                              DECISION_OUTCOMES, DRAFT_ACCEPTED, DRAFT_BONUS,
                              FRESH, NUM_CATEGORIES, PROMPT,
                              QUARANTINE_CLAMPED, RETRY_STITCHED,
                              REUSED_PREFIX, DecisionLog, LedgerError,
                              TokenLedger, categorize_draft_block,
                              load_dataset)

# ------------------------------------------------------------ unit behaviour


def test_row_records_in_order_and_conserves():
    led = TokenLedger()
    led.begin_row("r", 3)
    led.append("r", REUSED_PREFIX, 4)
    led.append("r", FRESH, 2)
    plane = led.row("r")
    assert plane.tolist() == [PROMPT] * 3 + [REUSED_PREFIX] * 4 + [FRESH] * 2
    led.finalize("r", 9)
    assert led.finalized == 1 and led.violations == 0


def test_finalize_rejects_length_mismatch():
    led = TokenLedger()
    led.begin_row("r", 2)
    led.append("r", FRESH, 1)
    with pytest.raises(LedgerError):
        led.finalize("r", 5)
    assert led.violations == 1


def test_disabled_ledger_is_inert():
    led = TokenLedger(enabled=False)
    led.begin_row("r", 3)
    led.append("r", FRESH, 100)
    led.finalize("r", 0)        # any expectation passes: nothing recorded
    assert led.category_counts().sum() == 0


def test_retry_category_switches_reuse_class():
    led = TokenLedger()
    led.note_retry("r", "deadline")
    assert led.retry_category("r") == RETRY_STITCHED
    led.note_retry("q", "quarantine")
    assert led.retry_category("q") == QUARANTINE_CLAMPED
    # with no recorded reason the conservative default is RETRY_STITCHED —
    # the category only prices draft tokens BEYOND base_draft_len, which
    # only a stitched re-admission can produce
    led.clear_retry("r")
    assert led.retry_category("r") == RETRY_STITCHED


def test_categorize_draft_block_carry_first():
    # one macro-step emits [carry | accepted drafts]: the first token is
    # the PREVIOUS step's correction/bonus sample, the rest are drafts
    assert categorize_draft_block(1, False) == [(FRESH, 1)]
    assert categorize_draft_block(1, True) == [(DRAFT_BONUS, 1)]
    assert categorize_draft_block(4, False) == [(FRESH, 1),
                                                (DRAFT_ACCEPTED, 3)]
    assert categorize_draft_block(4, True) == [(DRAFT_BONUS, 1),
                                               (DRAFT_ACCEPTED, 3)]
    assert categorize_draft_block(0, True) == []


def test_bind_unbind_stack():
    led = TokenLedger()
    assert led.bound_row(0) is None
    led.bind(["a", "b"])
    assert led.bound_row(0) == "a" and led.bound_row(1) == "b"
    led.bind(["c"])
    assert led.bound_row(0) == "c"
    led.unbind()
    assert led.bound_row(1) == "b"
    led.unbind()
    assert led.bound_row(0) is None


# ------------------------------------------------------- conservation property


def _replay(events, prompt_len):
    """Apply an event trace to a fresh ledger row; return expected length."""
    led = TokenLedger()
    led.begin_row("r", prompt_len)
    n = prompt_len
    for cat, k in events:
        led.append("r", cat, k)
        n += k
    led.finalize("r", n)
    return led


_CATS = (REUSED_PREFIX, DRAFT_ACCEPTED, DRAFT_BONUS, FRESH, RETRY_STITCHED,
         QUARANTINE_CLAMPED)


@settings(max_examples=100, deadline=None)
@given(prompt_len=st.integers(0, 16),
       events=st.lists(st.tuples(st.sampled_from(_CATS),
                                 st.integers(0, 8)), max_size=24))
def test_conservation_over_random_traces(prompt_len, events):
    led = _replay(events, prompt_len)
    total = prompt_len + sum(k for _, k in events)
    assert int(led.category_counts().sum()) == total
    assert led.violations == 0


def test_conservation_over_seeded_traces():
    """Deterministic twin of the property (runs with or without hypothesis)."""
    rng = np.random.RandomState(7)
    for _ in range(50):
        p = int(rng.randint(0, 16))
        events = [(int(rng.choice(_CATS)), int(rng.randint(0, 8)))
                  for _ in range(rng.randint(0, 24))]
        led = _replay(events, p)
        assert int(led.category_counts().sum()) == \
            p + sum(k for _, k in events)


def test_rollout_end_to_end_conservation():
    """A real drafted spec rollout: every emitted row's provenance plane
    partitions prompt+length exactly, and reuse counts match the rollout's
    own n_reused metric."""
    import jax
    import jax.numpy as jnp

    from repro.core.cache import RolloutCache
    from repro.core.spec_rollout import SpecConfig, rollout
    from repro.drafting import DraftConfig
    from repro.engine.generate import GenerateConfig
    from repro.models import model as M
    from repro.models.config import ModelConfig
    from repro.obs import configure, reset

    cfg = ModelConfig(name="t", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=32)
    params = M.init_lm(jax.random.PRNGKey(0), cfg)
    gen = GenerateConfig(max_new_tokens=8)
    spec = SpecConfig(variant="spec",
                      draft=DraftConfig(kind="ngram", draft_k=2))
    B, P = 4, 6
    rng = np.random.RandomState(3)
    prompts = jnp.asarray(rng.randint(3, 32, (B, P)), jnp.int32)
    mask = jnp.ones((B, P), bool)
    cache = RolloutCache()
    led = TokenLedger()
    configure(ledger=led)
    try:
        key = jax.random.PRNGKey(1)
        for step in range(3):   # step 0 cold, steps 1-2 verify + reuse
            key, sub = jax.random.split(key)
            rb = rollout(params, cfg, gen, spec, prompts, mask,
                         list(range(B)), cache, sub, step)
        assert led.violations == 0
        assert led.finalized == 3 * B
        counts = led.counts_dict()
        lens = np.asarray(rb.length)
        # the final step's rows conserve individually
        for rid, plane in led.rows().items():
            assert (plane != 0).all()   # no UNSET bytes survive finalize
        assert counts["prompt"] == 3 * B * P
        assert sum(counts.values()) == int(led.category_counts().sum())
    finally:
        reset()


# ------------------------------------------------------- decision round-trip


def test_decision_log_roundtrip(tmp_path):
    out = str(tmp_path / "dec")
    dec = DecisionLog(out, shard_rows=3)
    for i in range(8):
        dec.record(f"row{i % 2}", i,
                   {"surprisal": float(i), "draft_k": 2.0},
                   {"accepted": float(i % 3), "emitted": 1.0})
    dec.flush()
    assert dec.shards_written >= 2     # shard_rows=3 forced rotation
    ds = load_dataset(out)
    assert ds["features"].shape == (8, len(DECISION_FEATURES))
    assert ds["outcomes"].shape == (8, len(DECISION_OUTCOMES))
    si = DECISION_FEATURES.index("surprisal")
    np.testing.assert_array_equal(ds["features"][:, si],
                                  np.arange(8, dtype=np.float32))
    # unset columns default to 0
    qi = DECISION_FEATURES.index("queue_depth")
    assert (ds["features"][:, qi] == 0).all()
    assert sorted(set(ds["row"].tolist())) == ["row0", "row1"]


def test_decision_schema_drift_rejected(tmp_path):
    out = str(tmp_path / "dec")
    dec = DecisionLog(out)
    dec.record("r", 0, {}, {})
    dec.flush()
    import os

    shard = os.path.join(out, "decisions-00000.npz")
    with np.load(shard, allow_pickle=False) as z:
        data = dict(z)
    data["schema_version"] = np.int64(99)
    np.savez(shard, **data)
    with pytest.raises(ValueError, match="schema"):
        load_dataset(out)


# ------------------------------------------------------------- attribution


def test_attribution_prices_mechanisms():
    counts = {name: 0 for name in CATEGORY_NAMES}
    counts.update(prompt=10, reused_prefix=40, draft_accepted=20,
                  draft_bonus=5, fresh=25, shared_prompt_block=8)
    rep = attrib.build_report(counts, t_token_s=0.01, t_prompt_token_s=0.002,
                              actual_s=1.0)
    assert rep.total_tokens == 108
    assert rep.saved_s["spec_prefix"] == pytest.approx(0.40)
    assert rep.saved_s["draft"] == pytest.approx(0.20)
    assert rep.saved_s["shared_prompt"] == pytest.approx(8 * 0.002)
    assert rep.total_saved_s == pytest.approx(0.40 + 0.20 + 0.016)
    # counterfactual anchoring: baseline = actual + saved
    assert rep.baseline_s == pytest.approx(1.0 + rep.total_saved_s)
    d = rep.as_dict()
    assert d["attrib.speedup"] == pytest.approx(rep.baseline_s / 1.0)


def test_attribution_from_ledger_and_counter_events():
    led = TokenLedger()
    led.begin_row("r", 4)
    led.append("r", REUSED_PREFIX, 6)
    led.append("r", FRESH, 2)
    led.finalize("r", 12)
    rep = attrib.build_report(led, t_token_s=0.5)
    assert rep.counts["reused_prefix"] == 6
    assert rep.saved_s["spec_prefix"] == pytest.approx(3.0)
    evs = rep.counter_events(ts_s=1.5)
    assert evs and all(e["ts"] == 1.5 and e["track"] == "attrib"
                       for e in evs)


def test_measured_token_cost_fallbacks():
    assert attrib.measured_token_cost({}) is None
    assert attrib.measured_token_cost(
        {"serve.token_ms_mean": 20.0,
         "serve.token_ms_count": 5}) == pytest.approx(0.02)
    assert attrib.measured_token_cost(
        {"rollout.decode_s_sum": 4.0,
         "rollout.generated_tokens": 100.0}) == pytest.approx(0.04)
