"""§14 zero-overhead contract for the token-provenance ledger.

The ledger is host-side bookkeeping threaded around the jit'd programs,
never through them: lowering with a live ledger configured yields
byte-identical StableHLO, and every execution path — plain generate, the
drafted spec rollout, the slot engine, the paged engine, the 2×2 mesh
server — emits bit-identical tokens ledger on vs. off, while the on-runs
genuinely record conserving provenance planes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cache import RolloutCache
from repro.core.spec_rollout import SpecConfig, rollout
from repro.drafting import DraftConfig
from repro.engine.generate import GenerateConfig, generate
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.obs import configure, reset
from repro.obs.ledger import TokenLedger
from repro.serving import Request, SlotEngine

B, P, N, V = 4, 8, 10, 32


@pytest.fixture(scope="module")
def setup():
    cfg = ModelConfig(name="t", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=V)
    params = M.init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    prompts = [rng.randint(3, V, rng.randint(3, P + 1)).astype(np.int32)
               for _ in range(B)]
    keys = np.asarray(jax.vmap(lambda i: jax.random.fold_in(
        jax.random.PRNGKey(5), i))(jnp.arange(B)))
    return cfg, params, prompts, keys


@pytest.fixture()
def obs_state():
    yield
    reset()


def _batch(cfg, prompts):
    pm = np.zeros((len(prompts), P), np.int32)
    mk = np.zeros((len(prompts), P), bool)
    for i, p in enumerate(prompts):
        pm[i, P - len(p):] = p
        mk[i, P - len(p):] = True
    return jnp.asarray(pm), jnp.asarray(mk)


def test_hlo_identical_with_and_without_ledger(setup, obs_state):
    cfg, params, prompts, keys = setup
    gen = GenerateConfig(max_new_tokens=N)
    prompt, mask = _batch(cfg, prompts)
    key = jnp.asarray(keys)

    reset()
    base = generate.lower(params, cfg, gen, prompt, mask, key).as_text()
    configure(ledger=TokenLedger(enabled=True))
    on = generate.lower(params, cfg, gen, prompt, mask, key).as_text()
    assert on == base


def _run_rollout(cfg, params, prompts, drafting: bool):
    gen = GenerateConfig(max_new_tokens=N)
    draft = DraftConfig(kind="ngram", draft_k=2) if drafting \
        else DraftConfig()
    spec = SpecConfig(variant="spec", draft=draft)
    prompt, mask = _batch(cfg, prompts)
    cache = RolloutCache()
    out = []
    key = jax.random.PRNGKey(9)
    for step in range(2):       # step 0 cold generate, step 1 verify+resume
        key, sub = jax.random.split(key)
        rb = rollout(params, cfg, gen, spec, prompt, mask,
                     list(range(len(prompts))), cache, sub, step)
        out.append((np.asarray(rb.response).tolist(),
                    np.asarray(rb.length).tolist(),
                    np.asarray(rb.behaviour_logprobs).tolist()))
    return out


@pytest.mark.parametrize("drafting", [False, True],
                         ids=["rollout", "drafted_rollout"])
def test_rollout_tokens_bit_identical(setup, obs_state, drafting):
    cfg, params, prompts, keys = setup
    reset()
    base = _run_rollout(cfg, params, prompts, drafting)
    led = TokenLedger(enabled=True)
    configure(ledger=led)
    on = _run_rollout(cfg, params, prompts, drafting)
    assert on == base
    # not vacuous: both steps' rows finalized with zero violations
    assert led.finalized == 2 * B and led.violations == 0
    c = led.counts_dict()
    assert c["reused_prefix"] > 0       # step 1 really reused prefixes


def _run_slots(cfg, params, prompts, keys, draft=None, paged=False):
    gen = GenerateConfig(max_new_tokens=N)
    if paged:
        from repro.serving.paged_engine import PagedSlotEngine
        cfgp = cfg.replace(cache_layout="paged", kv_block_size=4)
        eng = PagedSlotEngine(params, cfgp, gen, num_slots=2,
                              prompt_width=P, chunk_steps=4)
    else:
        eng = SlotEngine(params, cfg, gen, num_slots=2, prompt_width=P,
                         chunk_steps=4, draft=draft)
    for i, p in enumerate(prompts):
        eng.submit(Request(request_id=i, prompt=p, key=keys[i],
                           max_new_tokens=N))
    resps = eng.run()
    return {i: (resps[i].tokens.tolist(), resps[i].length,
                np.asarray(resps[i].logprobs).tolist()) for i in resps}


@pytest.mark.parametrize("mode", ["slots", "drafted", "paged"])
def test_slot_engine_tokens_bit_identical_ledger(setup, obs_state, mode):
    cfg, params, prompts, keys = setup
    draft = DraftConfig(kind="ngram", draft_k=4) if mode == "drafted" \
        else None
    paged = mode == "paged"
    reset()
    base = _run_slots(cfg, params, prompts, keys, draft=draft, paged=paged)
    led = TokenLedger(enabled=True)
    configure(ledger=led)
    on = _run_slots(cfg, params, prompts, keys, draft=draft, paged=paged)
    assert on == base
    assert led.finalized == B and led.violations == 0
    for rid, plane in led.rows().items():
        assert (plane != 0).all()


@pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs >= 4 devices (CI obs lane sets "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
def test_mesh_server_tokens_bit_identical_ledger(setup, obs_state):
    from repro.distributed.mesh import MeshConfig
    from repro.serving.mesh_server import MeshSlotServer
    cfg, params, prompts, keys = setup
    gen = GenerateConfig(max_new_tokens=N)
    mesh = MeshConfig(data=2, model=2).build()

    def run(ledger):
        srv = MeshSlotServer(params, cfg, gen, mesh=mesh, num_slots=2,
                             prompt_width=P, chunk_steps=4, ledger=ledger)
        for i, p in enumerate(prompts):
            srv.submit(Request(request_id=i, prompt=p, key=keys[i],
                               max_new_tokens=N))
        resps = srv.run()
        return {i: (resps[i].tokens.tolist(), resps[i].length)
                for i in resps}

    reset()
    base = run(None)
    led = TokenLedger(enabled=True)
    on = run(led)
    assert on == base
    assert led.finalized == B and led.violations == 0
