"""MetricsRegistry unit tests (DESIGN.md §11): histogram percentile
accuracy, bucket-merge associativity across shards, type-driven registry
merge over the union of names, ratio re-derivation, all-array state
round-trip through checkpoint/io, and the summarize() percentile
extension."""
import itertools

import numpy as np
import pytest

from repro.core.metrics import summarize
from repro.obs import Histogram, MetricsRegistry
from repro.obs.registry import _BASE, bucket_edge, bucket_index


def test_bucket_index_log_spacing():
    assert bucket_index(1.0) == 0
    assert bucket_index(_BASE) == 1      # exact edges open a new bucket
    assert bucket_edge(bucket_index(5.0)) >= 5.0
    assert bucket_edge(bucket_index(5.0)) / 5.0 <= _BASE
    assert bucket_index(0.0) == bucket_index(-3.0)  # shared underflow bucket


def test_histogram_percentiles_within_bucket_tolerance():
    rng = np.random.default_rng(0)
    vals = rng.lognormal(mean=1.0, sigma=1.5, size=5000)
    h = Histogram.from_values(vals)
    for q in (50, 95, 99):
        exact = float(np.percentile(vals, q))
        est = h.percentile(q)
        # log-bucketed: relative error bounded by one bucket width
        assert exact / _BASE <= est <= exact * _BASE, (q, exact, est)
    assert h.percentile(0) >= h.vmin
    assert h.percentile(100) == pytest.approx(h.vmax)
    assert h.mean == pytest.approx(float(vals.mean()))


def test_histogram_merge_associative_and_commutative():
    rng = np.random.default_rng(1)
    parts = [Histogram.from_values(rng.exponential(scale=s, size=200))
             for s in (0.1, 3.0, 40.0)]

    def merged(order):
        out = Histogram()
        for i in order:
            out.combine(parts[i])
        return out

    ref = merged((0, 1, 2)).summary()
    for order in itertools.permutations(range(3)):
        got = merged(order).summary()
        # bucket counts are integers: everything bucket-derived is exact
        for k in ("count", "min", "max", "p50", "p95", "p99"):
            assert got[k] == ref[k], (order, k)
        # running float totals reassociate: equal up to rounding only
        assert got["sum"] == pytest.approx(ref["sum"])
        assert got["mean"] == pytest.approx(ref["mean"])
    assert ref["count"] == sum(p.count for p in parts)


def test_registry_merge_is_union_no_silent_drops():
    a = MetricsRegistry()
    a.inc("tokens", 10)
    a.set("steps", 5.0, agg="max")
    b = MetricsRegistry()
    b.inc("tokens", 7)
    b.inc("only_on_b", 3)               # the schema-drift case: a key one
    b.set("steps", 9.0, agg="max")      # shard has and another doesn't
    m = MetricsRegistry.merged([a, b])
    d = m.as_dict()
    assert d["tokens"] == 17.0
    assert d["only_on_b"] == 3.0        # survives the merge
    assert d["steps"] == 9.0


def test_registry_merge_order_invariant_across_shards():
    regs = []
    rng = np.random.default_rng(2)
    for shard in range(3):
        r = MetricsRegistry()
        r.inc("num", (shard + 1) * 10)
        r.inc("den", shard + 1)
        r.ratio("rate", "num", "den")
        for v in rng.exponential(scale=shard + 1, size=100):
            r.observe("lat_ms", v)
        regs.append(r)
    ref = MetricsRegistry.merged(regs).as_dict()
    for order in itertools.permutations(range(3)):
        got = MetricsRegistry.merged([regs[i] for i in order]).as_dict()
        assert set(got) == set(ref)
        assert got == pytest.approx(ref)    # float sums reassociate


def test_ratio_rederives_from_merged_counters():
    # sum-of-parts, not mean-of-means: an idle shard must not dilute
    busy = MetricsRegistry()
    busy.inc("acc", 90)
    busy.inc("prop", 100)
    busy.ratio("rate", "acc", "prop")
    idle = MetricsRegistry()
    idle.inc("acc", 0)
    idle.inc("prop", 0)
    idle.ratio("rate", "acc", "prop")
    d = MetricsRegistry.merged([busy, idle]).as_dict()
    assert d["rate"] == pytest.approx(0.9)      # NOT (0.9 + 0.0) / 2
    assert idle.as_dict()["rate"] == 0.0        # 0/0 reads as 0


def test_gauge_agg_modes():
    modes = {"max": 9.0, "min": 2.0, "sum": 11.0, "last": 9.0}
    for agg, expect in modes.items():
        a = MetricsRegistry()
        a.set("g", 2.0, agg=agg)
        b = MetricsRegistry()
        b.set("g", 9.0, agg=agg)
        assert MetricsRegistry.merged([a, b]).as_dict()["g"] == expect


def test_as_dict_histogram_expansion_schema():
    r = MetricsRegistry()
    for v in (1.0, 2.0, 4.0):
        r.observe("h", v)
    d = r.as_dict()
    for suffix in ("count", "sum", "mean", "min", "max", "p50", "p95", "p99"):
        assert f"h_{suffix}" in d
    assert d["h_count"] == 3.0 and d["h_sum"] == 7.0
    assert d["h_min"] == 1.0 and d["h_max"] == 4.0


def test_metric_names_reject_pytree_separator():
    r = MetricsRegistry()
    with pytest.raises(AssertionError):
        r.inc("bad/name")


def test_state_dict_roundtrip_through_checkpoint_io(tmp_path):
    from repro.checkpoint.io import load_pytree, save_pytree
    r = MetricsRegistry()
    r.inc("count", 42)
    r.set("peak", 7.5, agg="max")
    rng = np.random.default_rng(3)
    for v in rng.lognormal(size=500):
        r.observe("lat.verify_ms", v)
    r.observe("empty_adjacent", 0.0)    # underflow bucket persists too
    r.ratio("rate", "count", "count")
    # through the real npz writer: every leaf must be array-coercible
    save_pytree(str(tmp_path / "obs"), {"obs": r.state_dict()})
    tree, _ = load_pytree(str(tmp_path / "obs"))
    r2 = MetricsRegistry()
    r2.load_state_dict(tree["obs"])
    got, want = r2.as_dict(), r.as_dict()
    assert set(got) == set(want)
    # jnp.asarray on restore narrows float64 totals to f32: approx there,
    # exact on the int-backed counts
    assert got == pytest.approx(want, rel=1e-6)
    assert got["lat.verify_ms_count"] == want["lat.verify_ms_count"]
    assert got["count"] == 42.0
    # and the restored registry keeps accumulating correctly
    r2.observe("lat.verify_ms", 1.0)
    assert r2.as_dict()["lat.verify_ms_count"] == 501.0


def test_summarize_percentiles_extension():
    hist = [{"rollout_time": float(v)} for v in range(1, 101)]
    base = summarize(hist, ["rollout_time"])
    assert set(base) == {"rollout_time"}            # backward compatible
    ext = summarize(hist, ["rollout_time"], percentiles=True)
    assert ext["rollout_time"] == pytest.approx(50.5)
    assert ext["rollout_time_min"] == 1.0
    assert ext["rollout_time_max"] == 100.0
    p95 = ext["rollout_time_p95"]
    assert 95 / _BASE <= p95 <= 95 * _BASE
    assert ext["rollout_time_p50"] <= p95 <= ext["rollout_time_p99"]
