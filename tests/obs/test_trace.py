"""Tracer unit tests (DESIGN.md §11): fake-clock determinism, span
nesting/depth bookkeeping, the bounded ring buffer, and the deterministic
per-request sampling hash."""
import pytest

from repro.obs import NULL_TRACER, Tracer


class FakeClock:
    """Monotonic fake clock: each read advances by ``tick``."""

    def __init__(self, tick=1.0):
        self.t = 0.0
        self.tick = tick

    def __call__(self):
        self.t += self.tick
        return self.t


def test_span_nesting_and_depth():
    tr = Tracer(clock=FakeClock())
    with tr.span("outer", "main"):
        with tr.span("inner", "main"):
            pass
        with tr.span("inner2", "main"):
            pass
    spans = {s.name: s for s in tr.spans}
    assert spans["outer"].depth == 0
    assert spans["inner"].depth == 1 and spans["inner2"].depth == 1
    # closed in order: inner, inner2, outer
    assert [s.name for s in tr.spans] == ["inner", "inner2", "outer"]
    assert spans["outer"].t0 < spans["inner"].t0
    assert spans["outer"].t1 > spans["inner2"].t1


def test_fake_clock_determinism():
    def run():
        tr = Tracer(clock=FakeClock(0.5))
        h = tr.begin("a", "t1", x=1)
        tr.event("ev", "t1")
        tr.end(h, y=2)
        tr.complete("c", "t2", 10.0, 11.0)
        return [(s.name, s.track, s.t0, s.t1, s.depth, dict(s.args))
                for s in tr.spans] + \
               [(e.name, e.track, e.ts) for e in tr.events]

    assert run() == run()               # byte-for-byte deterministic
    tr = Tracer(clock=FakeClock(0.5))
    h = tr.begin("a", "t1")
    tr.end(h)
    (sp,) = tr.spans
    assert (sp.t0, sp.t1) == (0.5, 1.0)


def test_complete_and_event_explicit_timestamps():
    tr = Tracer(clock=FakeClock())
    tr.complete("stage", "lane", 3.0, 4.5, cat="x", foo="bar")
    tr.event("fault", "lane", ts=3.25)
    (sp,) = tr.spans
    assert (sp.t0, sp.t1, sp.dur) == (3.0, 4.5, 1.5)
    assert sp.args == {"foo": "bar"}
    (ev,) = tr.events
    assert ev.ts == 3.25                # no clock read when ts is given


def test_ring_buffer_bounds_and_drop_count():
    tr = Tracer(clock=FakeClock(), capacity=4)
    for i in range(10):
        tr.complete(f"s{i}", "t", float(i), float(i) + 0.5)
        tr.event(f"e{i}", "t", ts=float(i))
    assert len(tr.spans) == 4 and len(tr.events) == 4
    assert tr.dropped_spans == 6 and tr.dropped_events == 6
    assert [s.name for s in tr.spans] == ["s6", "s7", "s8", "s9"]


def test_disabled_tracer_records_nothing_and_reads_no_clock():
    reads = []

    def clock():
        reads.append(1)
        return 0.0

    tr = Tracer(enabled=False, clock=clock)
    h = tr.begin("a")
    assert h == -1
    tr.end(h)
    with tr.span("b"):
        pass
    tr.complete("c", "t", 0.0, 1.0)
    tr.event("d")
    assert not tr.spans and not tr.events
    assert reads == []                  # the zero-overhead contract
    assert not tr.sampled(0) and not tr.sampled(123)


def test_null_tracer_is_disabled():
    assert NULL_TRACER.enabled is False
    assert NULL_TRACER.begin("x") == -1


@pytest.mark.parametrize("rate", [0.0, 0.25, 0.5, 1.0])
def test_sampling_deterministic_and_roughly_proportional(rate):
    tr1 = Tracer(clock=FakeClock(), sample_rate=rate)
    tr2 = Tracer(clock=FakeClock(), sample_rate=rate)
    ids = range(1000)
    picks1 = [tr1.sampled(i) for i in ids]
    picks2 = [tr2.sampled(i) for i in ids]
    assert picks1 == picks2             # shard-invariant decision
    frac = sum(picks1) / 1000
    assert abs(frac - rate) < 0.1       # Knuth hash spreads uniformly


def test_tracks_enumeration_and_clear():
    tr = Tracer(clock=FakeClock())
    tr.complete("a", "engine", 0.0, 1.0)
    tr.complete("b", "req/3", 1.0, 2.0)
    tr.event("c", "req/7")
    assert tr.tracks() == ["engine", "req/3", "req/7"]
    tr.clear()
    assert tr.tracks() == [] and tr.dropped_spans == 0


def test_unbalanced_end_is_harmless():
    tr = Tracer(clock=FakeClock())
    tr.end(999)                         # never-opened handle: no-op
    h = tr.begin("a")
    tr.end(h)
    tr.end(h)                           # double-end: no-op
    assert len(tr.spans) == 1
