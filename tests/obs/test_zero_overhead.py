"""The §11 zero-overhead contract: instrumentation is host-side only.

Enabling the tracer/registry must not change WHAT is computed — the lowered
jit programs are textually identical (no ops baked into the graph, no extra
host syncs) and every execution path emits bit-identical tokens: plain
``generate``, the slot engine, the drafted slot engine, and the 2×2-mesh
slot server (skipped under < 4 devices, exercised by the CI obs lane).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.drafting import DraftConfig
from repro.engine.generate import GenerateConfig, generate
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.obs import MetricsRegistry, Tracer, configure, reset
from repro.serving import Request, SlotEngine

B, P, N, V = 4, 8, 10, 32


@pytest.fixture(scope="module")
def setup():
    cfg = ModelConfig(name="t", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=V)
    params = M.init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    prompts = [rng.randint(3, V, rng.randint(3, P + 1)).astype(np.int32)
               for _ in range(B)]
    keys = np.asarray(jax.vmap(lambda i: jax.random.fold_in(
        jax.random.PRNGKey(5), i))(jnp.arange(B)))
    return cfg, params, prompts, keys


@pytest.fixture()
def obs_state():
    """Restore the process-global tracer/registry after each test."""
    yield
    reset()


def _batch(cfg, prompts):
    pm = np.zeros((len(prompts), P), np.int32)
    mk = np.zeros((len(prompts), P), bool)
    for i, p in enumerate(prompts):
        pm[i, P - len(p):] = p
        mk[i, P - len(p):] = True
    return jnp.asarray(pm), jnp.asarray(mk)


def test_hlo_identical_with_and_without_obs(setup, obs_state):
    """The compiled program cannot depend on observability config: lowering
    ``generate`` with a live tracer configured yields byte-identical
    StableHLO to lowering with everything disabled."""
    cfg, params, prompts, keys = setup
    gen = GenerateConfig(max_new_tokens=N)
    prompt, mask = _batch(cfg, prompts)
    key = jnp.asarray(keys)

    reset()
    base = generate.lower(params, cfg, gen, prompt, mask, key).as_text()
    configure(tracer=Tracer(enabled=True), registry=MetricsRegistry())
    traced = generate.lower(params, cfg, gen, prompt, mask, key).as_text()
    assert traced == base


def _run_slots(cfg, params, prompts, keys, tracer, draft=None):
    gen = GenerateConfig(max_new_tokens=N)
    eng = SlotEngine(params, cfg, gen, num_slots=2, prompt_width=P,
                     chunk_steps=4, draft=draft, tracer=tracer)
    for i, p in enumerate(prompts):
        eng.submit(Request(request_id=i, prompt=p, key=keys[i],
                           max_new_tokens=N))
    resps = eng.run()
    return {i: (resps[i].tokens.tolist(), resps[i].length,
                np.asarray(resps[i].logprobs).tolist()) for i in resps}


@pytest.mark.parametrize("draft", [None, DraftConfig(kind="ngram", draft_k=4)],
                         ids=["slots", "drafted"])
def test_slot_engine_tokens_bit_identical(setup, obs_state, draft):
    cfg, params, prompts, keys = setup
    reset()
    base = _run_slots(cfg, params, prompts, keys, tracer=None, draft=draft)
    tr = Tracer(enabled=True)
    configure(tracer=tr, registry=MetricsRegistry())
    traced = _run_slots(cfg, params, prompts, keys, tracer=tr, draft=draft)
    assert traced == base
    # not vacuous: the traced run really recorded the request lifecycles
    assert any(t.startswith("req/") for t in tr.tracks())
    assert any(s.name == "request" for s in tr.spans)


def test_generate_tokens_bit_identical(setup, obs_state):
    cfg, params, prompts, keys = setup
    gen = GenerateConfig(max_new_tokens=N)
    prompt, mask = _batch(cfg, prompts)
    key = jnp.asarray(keys)
    reset()
    base = generate(params, cfg, gen, prompt, mask, key)
    configure(tracer=Tracer(enabled=True), registry=MetricsRegistry())
    traced = generate(params, cfg, gen, prompt, mask, key)
    for k in ("tokens", "logprobs", "length"):
        np.testing.assert_array_equal(np.asarray(traced[k]),
                                      np.asarray(base[k]))


@pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs >= 4 devices (CI obs/multi-device lanes set "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
def test_mesh_server_tokens_bit_identical(setup, obs_state):
    from repro.distributed.mesh import MeshConfig
    from repro.serving.mesh_server import MeshSlotServer
    cfg, params, prompts, keys = setup
    gen = GenerateConfig(max_new_tokens=N)
    mesh = MeshConfig(data=2, model=2).build()

    def run(tracer):
        srv = MeshSlotServer(params, cfg, gen, mesh=mesh, num_slots=2,
                             prompt_width=P, chunk_steps=4, tracer=tracer)
        for i, p in enumerate(prompts):
            srv.submit(Request(request_id=i, prompt=p, key=keys[i],
                               max_new_tokens=N))
        resps = srv.run()
        return {i: (resps[i].tokens.tolist(), resps[i].length)
                for i in resps}

    reset()
    base = run(None)
    tr = Tracer(enabled=True)
    traced = run(tr)
    assert traced == base
    # shard-prefixed lanes prove both shard engines reported into one tracer
    shards = {t.split("/", 1)[0] for t in tr.tracks()}
    assert {"shard0", "shard1"} <= shards
