"""Advantage estimators: GRPO group math, GAE vs brute force."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.rl.advantages import (gae_advantages, group_relative_advantages,
                                 terminal_reward_to_tokens, whiten)


def test_group_relative_zscore():
    r = jnp.array([1.0, 0.0, 1.0, 0.0,   0.0, 0.0, 0.0, 0.0])
    adv = np.asarray(group_relative_advantages(r, group_size=4))
    np.testing.assert_allclose(adv[:4], [1, -1, 1, -1], atol=1e-4)
    np.testing.assert_allclose(adv[4:], 0.0, atol=1e-5)  # degenerate group


def test_group_relative_no_std():
    r = jnp.array([1.0, 0.0, 0.0, 0.0])
    adv = np.asarray(group_relative_advantages(r, 4, use_std=False))
    np.testing.assert_allclose(adv, [0.75, -0.25, -0.25, -0.25], atol=1e-5)


def test_terminal_reward_placement():
    r = jnp.array([1.0, 0.5])
    lens = jnp.array([3, 1])
    tok = np.asarray(terminal_reward_to_tokens(r, lens, 5))
    np.testing.assert_allclose(tok[0], [0, 0, 1.0, 0, 0])
    np.testing.assert_allclose(tok[1], [0.5, 0, 0, 0, 0])


def _gae_brute(rew, vals, gamma, lam):
    T = len(rew)
    adv = np.zeros(T)
    for t in range(T):
        acc, disc = 0.0, 1.0
        for k in range(t, T):
            v_next = vals[k + 1] if k + 1 < T else 0.0
            delta = rew[k] + gamma * v_next - vals[k]
            acc += disc * delta
            disc *= gamma * lam
        adv[t] = acc
    return adv


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), gamma=st.floats(0.9, 1.0),
       lam=st.floats(0.8, 1.0))
def test_gae_matches_bruteforce(seed, gamma, lam):
    rng = np.random.default_rng(seed)
    T = 6
    rew = rng.normal(size=T)
    vals = rng.normal(size=T)
    want = _gae_brute(rew, vals, gamma, lam)
    got, returns = gae_advantages(jnp.asarray(rew)[None],
                                  jnp.asarray(vals)[None],
                                  jnp.ones((1, T), bool), gamma=gamma, lam=lam)
    np.testing.assert_allclose(np.asarray(got[0]), want, atol=1e-4)
    np.testing.assert_allclose(np.asarray(returns[0]), want + vals, atol=1e-4)


def test_gae_respects_mask():
    rew = jnp.array([[0.0, 1.0, 99.0, 99.0]])
    vals = jnp.array([[0.5, 0.5, 99.0, 99.0]])
    mask = jnp.array([[True, True, False, False]])
    adv, _ = gae_advantages(rew * mask, vals, mask, gamma=1.0, lam=1.0)
    a = np.asarray(adv[0])
    assert a[2] == 0.0 and a[3] == 0.0
    # within valid region equals brute force on the truncated problem
    want = _gae_brute([0, 1], [0.5, 0.5], 1.0, 1.0)
    np.testing.assert_allclose(a[:2], want, atol=1e-5)


def test_whiten():
    adv = jnp.array([[1.0, 2.0, 3.0, 0.0]])
    mask = jnp.array([[True, True, True, False]])
    w = np.asarray(whiten(adv, mask))
    assert abs(w[0, :3].mean()) < 1e-5
    assert abs(w[0, :3].std() - 1.0) < 1e-3
    assert w[0, 3] == 0.0
