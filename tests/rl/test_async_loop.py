"""Disaggregated async rollout ↔ train (DESIGN.md §12).

The §12 acceptance contract, end to end: K=0 under the deterministic
step-interleaved scheduler is token- and loss-identical to the synchronous
trainer; staleness ≤ K is IS-corrected; staleness > K re-verifies through
the SPEC-RL draft path; persistent weight-sync failure walks the mode
ladder down to synchronous; a producer kill + a failed sync (the seeded
chaos pair) completes without crashing; and the whole pair kill-and-resumes
byte-identically through checkpoint/io.
"""
import math

import jax
import numpy as np
import pytest

import repro.obs as obs
from repro.core import SpecConfig
from repro.core.backoff import BackoffConfig
from repro.data.dataset import PromptDataset
from repro.data.tokenizer import VOCAB_SIZE
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig
from repro.rewards.mathgen import MathTaskConfig, generate_problems
from repro.rl.async_loop import AsyncConfig, AsyncTrainer
from repro.rl.trainer import RLConfig, Trainer
from repro.serving.faults import FaultEvent, FaultPlan
from repro.serving.rollout_service import WeightSync


@pytest.fixture(autouse=True)
def _fresh_obs():
    obs.reset()
    yield
    obs.reset()


def _make_trainer(algo="grpo"):
    cfg = ModelConfig(name="tiny", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=VOCAB_SIZE,
                      max_seq_len=128)
    problems = generate_problems(MathTaskConfig(num_problems=8, max_operand=4))
    ds = PromptDataset(problems, max_prompt_len=10)
    rl = RLConfig(algo=algo, group_size=2, prompts_per_batch=4,
                  max_new_tokens=6, optim=AdamWConfig(lr=1e-3),
                  max_resample_rounds=1)
    spec = SpecConfig(variant="spec", lenience=math.e ** 0.5,
                      verify_impl="ref")
    return Trainer(cfg, rl, spec, ds, jax.random.PRNGKey(0))


def _fast_sync(max_attempts=3):
    return WeightSync(BackoffConfig(base=0.0, max_attempts=max_attempts),
                      sleep=lambda d: None)


# ------------------------------------------------------- determinism (K=0)

def test_k0_is_token_and_loss_identical_to_sync():
    steps = 3
    tr_sync = _make_trainer()
    sync_metrics = [tr_sync.train_step() for _ in range(steps)]

    at = AsyncTrainer(_make_trainer(),
                      AsyncConfig(staleness_window=0, buffer_capacity=2,
                                  schedule="pc"), sync=_fast_sync())
    async_metrics = at.run(steps)

    for ms, ma in zip(sync_metrics, async_metrics):
        assert ms["loss"] == ma["loss"], (ms["loss"], ma["loss"])
        assert ms["reward_mean"] == ma["reward_mean"]
    np.testing.assert_array_equal(np.asarray(tr_sync.last_rb.response),
                                  np.asarray(at.trainer.last_rb.response))
    for a, b in zip(jax.tree.leaves(tr_sync.params),
                    jax.tree.leaves(at.trainer.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert at.exact_steps == steps and at.is_steps == 0
    assert at.reverified == 0 and at.mode == "async"


# --------------------------------------------------- staleness window paths

def test_stale_within_window_gets_is_correction():
    # "ppcc": two collections land before each pair of optimizer steps, so
    # the second consumed trajectory is one version behind the policy
    at = AsyncTrainer(_make_trainer(),
                      AsyncConfig(staleness_window=2, buffer_capacity=4,
                                  schedule="ppcc"), sync=_fast_sync())
    out = at.run(4)
    assert at.exact_steps >= 1 and at.is_steps >= 1
    assert at.reverified == 0                 # window covers everything
    corrected = [m for m in out if m["staleness"] > 0]
    assert corrected and all("is_weight_mean" in m for m in corrected)
    assert all(np.isfinite(m["loss"]) for m in out)


def test_beyond_window_reverifies_instead_of_dropping():
    at = AsyncTrainer(_make_trainer(),
                      AsyncConfig(staleness_window=0, buffer_capacity=4,
                                  schedule="ppcc"), sync=_fast_sync())
    out = at.run(4)
    assert at.reverified >= 1                 # stale ⇒ re-verified, not shed
    assert at.buffer.shed == 0
    rev = [m for m in out if m.get("reverified")]
    assert rev and all(np.isfinite(m["loss"]) for m in rev)
    # re-verified steps still train on rewards computed under the fresh
    # response (the metrics schema matches the sync trainer's)
    assert all("reward_mean" in m and "collect_time" in m for m in out)


# ------------------------------------------------------- degradation ladder

def test_persistent_sync_failure_walks_the_ladder_to_sync():
    ws = _fast_sync(max_attempts=2)
    ws.fail_next(10 ** 6)                     # every publish attempt fails
    at = AsyncTrainer(_make_trainer(),
                      AsyncConfig(staleness_window=1, buffer_capacity=2,
                                  hard_staleness_cap=2, schedule="pc"),
                      sync=ws)
    out = at.run(8)
    assert len(out) == 8                      # degraded, never crashed
    assert at.mode == "sync" and at.degradations == 2
    assert at.sync_steps >= 1
    reg = obs.get_registry().as_dict()
    assert reg["async.degradation_level"] == 2.0
    assert reg["async.sync_failures"] >= 1
    assert reg["async.sync_retries"] >= 1
    # the service kept serving its last good version throughout
    assert at.service.version == 0


# ----------------------------------------------- failure-domain isolation

def test_seeded_chaos_producer_kill_plus_failed_sync():
    plan = FaultPlan([FaultEvent("kill", at_step=2),
                      FaultEvent("stall", at_step=4, count=1)])
    ws = _fast_sync(max_attempts=2)
    at = AsyncTrainer(_make_trainer(),
                      AsyncConfig(staleness_window=2, buffer_capacity=4,
                                  schedule="pc"),
                      faults=plan, sync=ws)
    ws.fail_next(2)                           # one publish fails fully
    out = at.run(6)
    assert len(out) == 6                      # completed despite the chaos
    assert at.producer_restarts == 1          # kill stayed in its domain
    assert at.service.stalled_ticks == 1
    assert ws.failures == 1
    assert all(np.isfinite(m["loss"]) for m in out)
    reg = obs.get_registry().as_dict()
    assert reg["async.producer_restarts"] == 1.0
    assert reg["async.sync_failures"] == 1.0
    # the pair degraded gracefully instead of dropping work
    at.buffer.check_invariants()


# --------------------------------------------------- exact kill-and-resume

def test_kill_and_resume_restores_buffer_and_version_state(tmp_path):
    acfg = AsyncConfig(staleness_window=1, buffer_capacity=4,
                       schedule="ppc")
    at = AsyncTrainer(_make_trainer(), acfg, sync=_fast_sync())
    at.run(2)                                 # leaves entries in the buffer
    assert len(at.buffer) >= 1
    at.save(str(tmp_path))

    at2 = AsyncTrainer(_make_trainer(), acfg, sync=_fast_sync())
    assert at2.restore(str(tmp_path))

    # byte-identical buffer/version/service state
    s1, s2 = at.state_dict(), at2.state_dict()
    f1, t1 = jax.tree.flatten(s1)
    f2, t2 = jax.tree.flatten(s2)
    assert t1 == t2
    for a, b in zip(f1, f2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert at2.version == at.version
    assert at2.service.version == at.service.version
    assert len(at2.buffer) == len(at.buffer)

    # ...and the continuation is identical too (shared-RNG replay)
    m1 = at.run(2)
    m2 = at2.run(2)
    assert [m["loss"] for m in m1] == [m["loss"] for m in m2]
    np.testing.assert_array_equal(np.asarray(at.trainer.last_rb.response),
                                  np.asarray(at2.trainer.last_rb.response))


def test_restore_on_empty_dir_is_a_fresh_start(tmp_path):
    at = AsyncTrainer(_make_trainer(), AsyncConfig(), sync=_fast_sync())
    assert not at.restore(str(tmp_path / "nothing"))
