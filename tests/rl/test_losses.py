"""Policy / value losses: clipping semantics, KL estimator, aggregation."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.rl.losses import (PolicyLossConfig, kl_to_reference, masked_mean,
                             policy_loss, value_loss)


def _cfg(**kw):
    return PolicyLossConfig(**kw)


def test_ratio_one_gives_negative_mean_advantage():
    lp = jnp.zeros((2, 4))
    adv = jnp.ones((2, 4))
    mask = jnp.ones((2, 4), bool)
    loss, info = policy_loss(lp, lp, adv, mask, _cfg())
    assert float(loss) == pytest.approx(-1.0)
    assert float(info["clip_frac"]) == 0.0
    assert float(info["approx_kl"]) == 0.0


def test_positive_advantage_clipped_above():
    """ratio >> 1+clip_high with adv>0: surrogate capped at (1+c_h)*adv."""
    lp_old = jnp.zeros((1, 1))
    lp_new = jnp.full((1, 1), 1.0)              # ratio = e
    adv = jnp.ones((1, 1))
    mask = jnp.ones((1, 1), bool)
    loss, info = policy_loss(lp_new, lp_old, adv, mask,
                             _cfg(clip_high=0.28))
    assert float(loss) == pytest.approx(-1.28, abs=1e-5)
    assert float(info["clip_frac"]) == 1.0


def test_negative_advantage_dual_clip():
    """Very large ratio with adv<0 is floored by the dual clip constant."""
    lp_old = jnp.zeros((1, 1))
    lp_new = jnp.full((1, 1), 5.0)              # ratio = e^5 ~ 148
    adv = -jnp.ones((1, 1))
    mask = jnp.ones((1, 1), bool)
    loss, _ = policy_loss(lp_new, lp_old, adv, mask, _cfg(clip_c=10.0))
    # surrogate = max(min(ratio*adv, clip*adv), c*adv) = -10
    assert float(loss) == pytest.approx(10.0, abs=1e-4)


def test_aggregation_token_vs_seq():
    lp_old = jnp.zeros((2, 4))
    lp_new = jnp.zeros((2, 4))
    adv = jnp.array([[1.0, 1, 1, 1], [2.0, 0, 0, 0]])
    mask = jnp.array([[True] * 4, [True, False, False, False]])
    loss_seq, _ = policy_loss(lp_new, lp_old, adv, mask, _cfg(agg="seq"))
    loss_tok, _ = policy_loss(lp_new, lp_old, adv, mask, _cfg(agg="token"))
    # seq: mean(mean([1,1,1,1]), mean([2])) = 1.5; token: mean over 5 = 1.2
    assert float(loss_seq) == pytest.approx(-1.5, abs=1e-5)
    assert float(loss_tok) == pytest.approx(-1.2, abs=1e-5)


def test_kl_estimator_nonneg_zero_at_equal():
    lp = jnp.array([[-1.0, -2.0]])
    mask = jnp.ones((1, 2), bool)
    assert float(kl_to_reference(lp, lp, mask)) == pytest.approx(0.0)
    lp_ref = lp + jnp.array([[0.5, -0.5]])
    assert float(kl_to_reference(lp, lp_ref, mask)) > 0.0


def test_value_loss_clipping():
    old_v = jnp.zeros((1, 1))
    returns = jnp.ones((1, 1))
    mask = jnp.ones((1, 1), bool)
    # new value moved way past the clip: loss uses the worse (clipped) branch
    v = jnp.full((1, 1), 2.0)
    l = value_loss(v, returns, old_v, mask, clip=0.2)
    assert float(l) == pytest.approx(0.5 * max((2 - 1) ** 2, (0.2 - 1) ** 2))


def test_masked_mean():
    x = jnp.array([[1.0, 100.0]])
    m = jnp.array([[True, False]])
    assert float(masked_mean(x, m)) == pytest.approx(1.0)
