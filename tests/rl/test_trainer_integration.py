"""Trainer integration: all three algorithms x SPEC-RL run end-to-end;
GRPO improves reward on a trivial task from random init."""
import math

import jax
import numpy as np
import pytest

from repro.core import SpecConfig
from repro.data.dataset import PromptDataset
from repro.data.tokenizer import VOCAB_SIZE
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig
from repro.rewards.mathgen import MathTaskConfig, generate_problems
from repro.rl.trainer import RLConfig, Trainer


def _make_trainer(algo, variant="spec", steps_cfg=None, seed=0):
    cfg = ModelConfig(name="tiny", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=VOCAB_SIZE,
                      max_seq_len=128)
    problems = generate_problems(MathTaskConfig(num_problems=8, max_operand=4))
    ds = PromptDataset(problems, max_prompt_len=10)
    rl = RLConfig(algo=algo, group_size=2, prompts_per_batch=4,
                  max_new_tokens=6, optim=AdamWConfig(lr=1e-3),
                  max_resample_rounds=1, **(steps_cfg or {}))
    spec = SpecConfig(variant=variant, lenience=math.e ** 0.5,
                      verify_impl="ref")
    return Trainer(cfg, rl, spec, ds, jax.random.PRNGKey(seed))


@pytest.mark.parametrize("algo", ["grpo", "ppo", "dapo"])
def test_algo_runs_with_spec_rl(algo):
    tr = _make_trainer(algo)
    for _ in range(3):
        m = tr.train_step()
    assert np.isfinite(m["loss"])
    assert m["total_generated_tokens"] > 0
    if algo == "ppo":
        assert "critic_loss" in m
    if algo == "dapo":
        assert m["gen_steps"] >= 3   # dynamic sampling may add rounds


def test_spec_rl_reduces_generated_tokens():
    """After the cold-start epoch, SPEC-RL reuses: fewer generated tokens
    than the vanilla variant at the same steps (paper Table 1 mechanism)."""
    tr_spec = _make_trainer("grpo", variant="spec", seed=1)
    tr_off = _make_trainer("grpo", variant="off", seed=1)
    for _ in range(4):
        tr_spec.train_step()
        tr_off.train_step()
    assert tr_spec.total_generated_tokens < tr_off.total_generated_tokens


def test_kl_ref_tracked_for_grpo():
    tr = _make_trainer("grpo")
    m = tr.train_step()
    assert "kl_ref" in m


@pytest.mark.slow
def test_grpo_learns_single_digit_addition():
    """Reward improves on an easy task within a modest budget."""
    cfg = ModelConfig(name="learn", num_layers=2, d_model=96, num_heads=4,
                      num_kv_heads=2, d_ff=192, vocab_size=VOCAB_SIZE,
                      max_seq_len=64)
    problems = generate_problems(MathTaskConfig(
        num_problems=6, min_operand=1, max_operand=3, ops="+"))
    ds = PromptDataset(problems, max_prompt_len=8)
    rl = RLConfig(algo="grpo", group_size=8, prompts_per_batch=6,
                  max_new_tokens=4, optim=AdamWConfig(lr=4e-3),
                  temperature=1.0)
    tr = Trainer(cfg, rl, SpecConfig(variant="spec", verify_impl="ref"), ds,
                 jax.random.PRNGKey(0))
    rewards = [tr.train_step()["reward_mean"] for _ in range(30)]
    early = np.mean(rewards[:5])
    late = np.mean(rewards[-5:])
    assert late > early + 0.1, f"no learning: early={early}, late={late}"
