"""Bounded trajectory buffer (DESIGN.md §12): watermark backpressure,
shed-oldest overflow, counter reconciliation, exact state round-trip.

The invariants are property-tested through tests/hypothesis_compat.py —
with hypothesis absent the @given tests skip and the plain ones still run.
"""
import numpy as np
import pytest
from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.core.spec_rollout import RolloutBatch
from repro.data.dataset import PromptBatch
from repro.rl.traj_buffer import TrajBuffer, Trajectory


def _traj(version=0, producer=0, seed=0):
    rng = np.random.RandomState(seed)
    B, P, N = 2, 4, 3
    batch = PromptBatch(tokens=rng.randint(0, 32, (B, P)).astype(np.int32),
                        mask=np.ones((B, P), bool),
                        cache_keys=[seed * B + i for i in range(B)],
                        answers=[1, 2], problem_ids=[0, 1], epoch=version)
    rb = RolloutBatch(prompt=batch.tokens, prompt_mask=batch.mask,
                      response=rng.randint(0, 32, (B, N)).astype(np.int32),
                      response_mask=np.ones((B, N), bool),
                      behaviour_logprobs=rng.randn(B, N).astype(np.float32),
                      length=np.full(B, N, np.int32),
                      metrics={"collect_time": 0.01 * seed})
    return Trajectory(batch=batch, rb=rb,
                      rewards=rng.rand(B).astype(np.float32),
                      version=version, producer=producer)


# ------------------------------------------------------------- plain tests

def test_watermark_throttles_before_capacity_sheds():
    buf = TrajBuffer(capacity=3, high_watermark=2)
    assert buf.put(_traj(0)) is None
    assert not buf.should_throttle()
    assert buf.put(_traj(0, seed=1)) is None
    assert buf.should_throttle()            # at watermark: producer backs off
    shed = buf.put(_traj(1, seed=2))        # forced put still accepted
    assert shed is None and len(buf) == 3
    shed = buf.put(_traj(2, seed=3))        # past capacity: oldest goes
    assert shed is not None and shed.version == 0
    assert len(buf) == 3 and buf.shed == 1
    buf.check_invariants()


def test_fifo_order_and_seq_tags():
    buf = TrajBuffer(capacity=4)
    for v in range(3):
        buf.put(_traj(v, seed=v))
    got = [buf.get() for _ in range(3)]
    assert [t.version for t in got] == [0, 1, 2]
    assert [t.seq for t in got] == [0, 1, 2]
    assert buf.get() is None                # starved, not an error
    buf.check_invariants()


def test_version_monotonicity_asserted_per_producer():
    buf = TrajBuffer(capacity=4)
    buf.put(_traj(5, producer=0))
    buf.put(_traj(3, producer=1))           # other producer: independent
    with pytest.raises(AssertionError):
        buf.put(_traj(4, producer=0))       # time travel is a bug


def test_state_dict_round_trip_is_exact():
    buf = TrajBuffer(capacity=3, high_watermark=2)
    for v in range(4):                      # forces one shed
        buf.put(_traj(v, seed=v))
    buf.get()
    buf.note_throttled()
    st_ = buf.state_dict()
    buf2 = TrajBuffer(capacity=1)
    buf2.load_state_dict(st_)
    assert buf2.counters() == buf.counters()
    assert buf2.capacity == 3 and buf2.high_watermark == 2
    a, b = buf2.get(), buf.get()
    assert a.version == b.version and a.seq == b.seq
    np.testing.assert_array_equal(a.rb.response, b.rb.response)
    np.testing.assert_array_equal(a.rewards, b.rewards)
    assert a.rb.metrics == b.rb.metrics
    assert a.batch.cache_keys == b.batch.cache_keys


# --------------------------------------------------------- property tests

if HAVE_HYPOTHESIS:
    OPS = st.lists(st.tuples(st.sampled_from(["put", "get"]),
                             st.integers(0, 2)),     # producer id
                   max_size=40)
else:                                                 # pragma: no cover
    OPS = None


@settings(max_examples=50, deadline=None)
@given(ops=OPS, capacity=st.integers(1, 5))
def test_prop_occupancy_bounded_and_counters_reconcile(ops, capacity):
    buf = TrajBuffer(capacity=capacity)
    version = {0: 0, 1: 0, 2: 0}
    for op, prod in ops:
        if op == "put":
            version[prod] += 1              # monotone by construction
            buf.put(_traj(version[prod], producer=prod, seed=version[prod]))
        else:
            buf.get()
        assert len(buf) <= buf.capacity
        buf.check_invariants()              # submitted == consumed+shed+occ


@settings(max_examples=50, deadline=None)
@given(versions=st.lists(st.integers(0, 100), min_size=1, max_size=20))
def test_prop_versions_monotone_per_producer(versions):
    buf = TrajBuffer(capacity=4)
    last = None
    for v in versions:
        if last is not None and v < last:
            with pytest.raises(AssertionError):
                buf.put(_traj(v, seed=v))
            continue                        # rejected put changes nothing
        buf.put(_traj(v, seed=v))
        last = v
        buf.check_invariants()
    # drain: consumed versions come out monotone (FIFO of monotone input)
    out = []
    while (t := buf.get()) is not None:
        out.append(t.version)
    assert out == sorted(out)
