"""Trainer watchdog (DESIGN.md §10): snapshot-on-healthy, restore-last-good
on poisoned steps, skip (not replay) the poisoned batch."""
import math

import jax
import numpy as np
import pytest

from repro.checkpoint.io import read_latest
from repro.core import SpecConfig
from repro.data.dataset import PromptDataset
from repro.data.tokenizer import VOCAB_SIZE
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig
from repro.rewards.mathgen import MathTaskConfig, generate_problems
from repro.rl.trainer import RLConfig, Trainer
from repro.rl.watchdog import TrainWatchdog, WatchdogConfig


def _make_trainer(watchdog=None, algo="grpo"):
    cfg = ModelConfig(name="tiny", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=VOCAB_SIZE,
                      max_seq_len=128)
    problems = generate_problems(MathTaskConfig(num_problems=8, max_operand=4))
    ds = PromptDataset(problems, max_prompt_len=10)
    rl = RLConfig(algo=algo, group_size=2, prompts_per_batch=4,
                  max_new_tokens=6, optim=AdamWConfig(lr=1e-3),
                  max_resample_rounds=1)
    spec = SpecConfig(variant="spec", lenience=math.e ** 0.5,
                      verify_impl="ref")
    return Trainer(cfg, rl, spec, ds, jax.random.PRNGKey(0),
                   watchdog=watchdog)


def test_healthy_steps_snapshot_on_cadence(tmp_path):
    wd = TrainWatchdog(WatchdogConfig(checkpoint_dir=str(tmp_path),
                                      snapshot_every=2))
    tr = _make_trainer(watchdog=wd)
    metrics = [tr.train_step() for _ in range(3)]
    # first healthy step snapshots unconditionally, then every cadence-th
    assert wd.snapshots >= 2
    assert read_latest(str(tmp_path)) is not None
    assert metrics[-1]["watchdog_snapshots"] == float(wd.snapshots)
    assert metrics[-1]["watchdog_restores"] == 0.0


def test_poisoned_step_restores_last_good(tmp_path):
    wd = TrainWatchdog(WatchdogConfig(checkpoint_dir=str(tmp_path),
                                      snapshot_every=1))
    tr = _make_trainer(watchdog=wd)
    tr.train_step()
    good = jax.tree.map(np.asarray, tr.params)
    step_before = tr.step_idx

    # simulate a poisoned update landing on the params
    tr.params = jax.tree.map(lambda x: x * np.nan, tr.params)
    m = {"loss": float("nan"), "reward_mean": 0.0}
    wd.after_step(tr, m)

    assert m.get("watchdog_restored") == 1.0
    assert wd.nonfinite_steps == 1 and wd.restores == 1
    for a, b in zip(jax.tree.leaves(tr.params), jax.tree.leaves(good)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # step counter NOT rolled back: the poisoned batch is skipped, the
    # next step trains on fresh data with the restored params
    assert tr.step_idx == step_before
    m2 = tr.train_step()
    assert np.isfinite(m2["loss"])
    assert m2["watchdog_restores"] == 1.0


def test_stalled_rollout_counts_as_poisoned(tmp_path):
    wd = TrainWatchdog(WatchdogConfig(checkpoint_dir=str(tmp_path),
                                      snapshot_every=1, max_collect_time=0.5))
    tr = _make_trainer(watchdog=wd)
    tr.train_step()
    m = {"loss": 0.1, "reward_mean": 0.0, "collect_time": 10.0}
    wd.after_step(tr, m)
    assert wd.stalled_steps == 1 and wd.restores == 1
    assert m["watchdog_restored"] == 1.0


def test_restore_budget_exhaustion_raises(tmp_path):
    wd = TrainWatchdog(WatchdogConfig(checkpoint_dir=str(tmp_path),
                                      snapshot_every=1, max_restores=0))
    tr = _make_trainer(watchdog=wd)
    tr.train_step()
    with pytest.raises(RuntimeError, match="restore budget"):
        wd.after_step(tr, {"loss": float("nan")})


def test_poisoned_before_any_snapshot_skips(tmp_path):
    wd = TrainWatchdog(WatchdogConfig(checkpoint_dir=str(tmp_path)))
    tr = _make_trainer(watchdog=wd)
    m = {"loss": float("nan")}
    wd.after_step(tr, m)                        # nothing to restore yet
    assert wd.skipped_no_snapshot == 1 and wd.restores == 0
    assert "watchdog_restored" not in m


def test_restore_carries_cache_and_counters(tmp_path):
    """The rollout cache and generation counters travel with the snapshot:
    a restored trainer keeps its SPEC-RL reuse warm."""
    wd = TrainWatchdog(WatchdogConfig(checkpoint_dir=str(tmp_path),
                                      snapshot_every=1))
    tr = _make_trainer(watchdog=wd)
    tr.train_step()
    cached = sorted(tr.cache._store)
    gen_steps = tr.gen_steps
    tr.cache._store.clear()                     # simulated corruption
    wd.after_step(tr, {"loss": float("nan")})
    assert sorted(tr.cache._store) == cached
    assert tr.gen_steps == gen_steps


def test_service_stall_routes_through_restore(tmp_path):
    """§12: a stalled rollout *service* surfaces as the consumer waiting
    far past its normal fresh-trajectory cadence — same restore-last-good
    verdict as an in-process collect stall."""
    wd = TrainWatchdog(WatchdogConfig(checkpoint_dir=str(tmp_path),
                                      snapshot_every=1,
                                      max_service_wait=1.0))
    tr = _make_trainer(watchdog=wd)
    tr.train_step()
    good = jax.tree.map(np.asarray, tr.params)
    tr.params = jax.tree.map(lambda x: x * 2.0, tr.params)
    m = {"loss": 0.1, "reward_mean": 0.0, "service_wait_s": 5.0}
    wd.after_step(tr, m)
    assert wd.service_stalled_steps == 1 and wd.restores == 1
    assert m["watchdog_restored"] == 1.0
    for a, b in zip(jax.tree.leaves(tr.params), jax.tree.leaves(good)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_service_stall_adaptive_p95(tmp_path):
    """No absolute cap set: the adaptive p95 × mult detector arms off the
    run's own healthy trajectory waits and trips on the outlier."""
    wd = TrainWatchdog(WatchdogConfig(checkpoint_dir=str(tmp_path),
                                      snapshot_every=1, stall_p95_mult=10.0,
                                      stall_min_samples=4))
    tr = _make_trainer(watchdog=wd)
    tr.train_step()
    for i in range(5):                          # healthy waits ~10ms
        wd.after_step(tr, {"loss": 0.1, "reward_mean": 0.0,
                           "service_wait_s": 0.01 + 0.001 * i})
    assert wd.service_stalled_steps == 0
    m = {"loss": 0.1, "reward_mean": 0.0, "service_wait_s": 30.0}
    wd.after_step(tr, m)
    assert wd.service_stalled_steps == 1
    assert m["watchdog_service_wait_p95"] > 0


def test_staleness_gauge_blowout_is_a_service_stall(tmp_path):
    wd = TrainWatchdog(WatchdogConfig(checkpoint_dir=str(tmp_path),
                                      snapshot_every=1,
                                      max_service_staleness=4.0))
    tr = _make_trainer(watchdog=wd)
    tr.train_step()
    m = {"loss": 0.1, "reward_mean": 0.0, "service_staleness": 9.0}
    wd.after_step(tr, m)
    assert wd.service_stalled_steps == 1 and m["watchdog_restored"] == 1.0
