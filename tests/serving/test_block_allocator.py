"""BlockAllocator edge cases (DESIGN.md §13): pool exhaustion, CoW forks
under a full pool, refcount lifecycle across share/fork/free and exact
state round-trip, plus a hypothesis conservation property — blocks in use,
the free list and the pinned sink always partition the pool exactly."""
import numpy as np
import pytest

from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.serving.block_table import (BlockAllocator, PoolExhausted,
                                       identity_table)


def test_alloc_exhaustion_is_all_or_nothing():
    a = BlockAllocator(6, 4)               # sink + 5 usable
    row = a.alloc(3)
    assert len(row) == 3 and 0 not in row
    with pytest.raises(PoolExhausted):
        a.alloc(3)                         # only 2 left: must not partially
    assert a.free_blocks == 2              # nothing leaked by the failure
    assert a.alloc_failures == 1
    a.check()
    a.free_table(row)
    assert a.free_blocks == 5
    a.check()


def test_fork_when_pool_full_raises_and_leaks_nothing():
    a = BlockAllocator(4, 4)               # sink + 3
    row = a.alloc(3)
    shared = row[0]
    a.share(shared)                        # refcount 2, pool now full
    with pytest.raises(PoolExhausted):
        a.fork(shared)
    assert a.refcount[shared] == 2         # failed fork must not decref
    a.check()
    a.free(row[2])                         # one block back -> fork succeeds
    nb = a.fork(shared)
    assert nb != shared and a.refcount[shared] == 1 and a.refcount[nb] == 1
    a.check()


def test_refcount_lifecycle_share_fork_free():
    a = BlockAllocator(8, 4)
    row = a.alloc(2)
    b = row[0]
    assert a.share(b) == b and a.share(b) == b
    assert a.refcount[b] == 3
    a.free(b)                              # one sharer leaves
    assert a.refcount[b] == 2
    nb = a.fork(b)                         # forker leaves, takes a copy
    assert a.refcount[b] == 1 and a.refcount[nb] == 1
    assert a.cow_forks == 1
    a.free(b)
    a.free(nb)
    a.free(row[1])
    assert a.blocks_in_use == 0
    a.check()
    with pytest.raises(AssertionError):
        a.free(b)                          # double free must be loud


def test_sink_is_pinned():
    a = BlockAllocator(3, 4)
    a.free(BlockAllocator.SINK)            # no-op, never returns to the pool
    assert a.free_blocks == 2
    rows = [a.alloc(1)[0] for _ in range(2)]
    assert BlockAllocator.SINK not in rows
    with pytest.raises(AssertionError):
        a.share(BlockAllocator.SINK)


def test_peak_and_state_roundtrip():
    a = BlockAllocator(10, 8)
    r1, r2 = a.alloc(4), a.alloc(3)
    a.share(r1[0])
    a.free_table(r2)
    assert a.peak_blocks_in_use == 7
    b = BlockAllocator(10, 8)
    b.load_state_dict(a.state_dict())
    assert b.free_blocks == a.free_blocks
    assert np.array_equal(b.refcount, a.refcount)
    assert (b.cow_forks, b.alloc_failures, b.peak_blocks_in_use) == \
        (a.cow_forks, a.alloc_failures, a.peak_blocks_in_use)
    b.check()
    # the restored allocator keeps allocating consistently
    got = b.alloc(b.free_blocks)
    assert len(set(got)) == len(got) and 0 not in got
    b.check()


def test_identity_table_layout():
    t = identity_table(3, 4)
    assert t.shape == (3, 4)
    assert np.array_equal(np.asarray(t).reshape(-1), np.arange(12))
    t2 = identity_table(2, 3, offset=5)
    assert np.asarray(t2).min() == 5


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=60, deadline=None)
@given(st.integers(2, 24), st.lists(st.integers(0, 5), max_size=40))
def test_conservation_property(num_blocks, ops):
    """Random interleavings of alloc/share/fork/free never violate the pool
    partition invariant or leak/duplicate a block (allocator.check())."""
    rng = np.random.RandomState(num_blocks + len(ops))
    a = BlockAllocator(num_blocks, 4)
    live = []                              # blocks we hold a ref on
    for op in ops:
        try:
            if op <= 1:                    # alloc 1-2 blocks
                live.extend(a.alloc(op + 1))
            elif op == 2 and live:
                b = live[rng.randint(len(live))]
                a.share(b)
                live.append(b)
            elif op == 3 and live:
                b = live[rng.randint(len(live))]
                if a.refcount[b] > 1:
                    live[live.index(b)] = a.fork(b)
            elif op >= 4 and live:
                a.free(live.pop(rng.randint(len(live))))
        except PoolExhausted:
            pass
        a.check()
    for b in live:
        a.free(b)
    a.check()
    assert a.blocks_in_use == 0
