"""Slot engine with the §9 draft engine: greedy token identity vs the
undrafted engine AND fixed-batch generate, draft telemetry surfaces, and
spec-prefix admission composing with continuation drafting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.drafting import DraftConfig
from repro.engine.generate import GenerateConfig, generate
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.serving import Request
from repro.serving.mesh_server import make_slot_engine

P, N, V = 8, 12, 32


@pytest.fixture(scope="module")
def setup():
    cfg = ModelConfig(name="t", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=V)
    params = M.init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    prompts = [rng.randint(3, V, rng.randint(3, P + 1)).astype(np.int32)
               for _ in range(6)]
    keys = np.asarray(jax.vmap(lambda i: jax.random.fold_in(
        jax.random.PRNGKey(5), i))(jnp.arange(6)))
    return cfg, params, prompts, keys


def _reqs(prompts, keys, corpus=None):
    out = []
    for i, p in enumerate(prompts):
        r = Request(request_id=i, prompt=p, key=keys[i], max_new_tokens=N)
        if corpus is not None:
            r.ngram_corpus = corpus[i]
        out.append(r)
    return out


def _run(cfg, params, gen, prompts, keys, draft, corpus=None, slots=3):
    eng = make_slot_engine(params, cfg, gen, num_slots=slots, prompt_width=P,
                           draft=draft)
    for r in _reqs(prompts, keys, corpus):
        eng.submit(r)
    resp = eng.run()
    return {i: resp[i].tokens.tolist() for i in resp}, eng.stats()


def test_drafted_slots_greedy_identity(setup):
    cfg, params, prompts, keys = setup
    gen = GenerateConfig(max_new_tokens=N, temperature=0.0)
    base, s0 = _run(cfg, params, gen, prompts, keys, None)
    drafted, s1 = _run(cfg, params, gen, prompts, keys,
                       DraftConfig(kind="ngram", draft_k=4))
    assert drafted == base
    # the drafted engine really batched multiple tokens per forward
    assert s1["engine_steps"] < s0["engine_steps"]
    assert s1["tokens_per_forward"] > 1.0
    assert 0.0 < s1["accept_rate"] <= 1.0
    assert s1["mean_draft_len"] > 0.0
    # undrafted engines expose the same schema, zeroed
    assert s0["tokens_per_forward"] == 0.0 and s0["draft_proposed"] == 0.0


def test_drafted_slots_greedy_identity_vs_fixed_batch(setup):
    """Same invariant chain as the undrafted engine: slot-scheduled drafted
    output == fixed-batch generate, request by request."""
    cfg, params, prompts, keys = setup
    gen = GenerateConfig(max_new_tokens=N, temperature=0.0)
    drafted, _ = _run(cfg, params, gen, prompts, keys,
                      DraftConfig(kind="ngram", draft_k=4), slots=2)
    toks = np.zeros((len(prompts), P), np.int32)
    mask = np.zeros((len(prompts), P), bool)
    for i, p in enumerate(prompts):
        toks[i, P - len(p):] = p
        mask[i, P - len(p):] = True
    ref = generate(params, cfg, gen, jnp.asarray(toks), jnp.asarray(mask),
                   jnp.asarray(keys))
    for i in range(len(prompts)):
        L = int(ref["length"][i])
        assert drafted[i] == np.asarray(ref["tokens"][i][:L]).tolist()


def test_corpus_improves_throughput_not_tokens(setup):
    cfg, params, prompts, keys = setup
    gen = GenerateConfig(max_new_tokens=N, temperature=0.0)
    draft = DraftConfig(kind="ngram", draft_k=4)
    base, s0 = _run(cfg, params, gen, prompts, keys, draft)
    corpus = [[np.asarray(base[i], np.int32)] for i in range(len(prompts))]
    again, s1 = _run(cfg, params, gen, prompts, keys, draft, corpus=corpus)
    assert again == base
    assert s1["accept_rate"] > s0["accept_rate"]
    assert s1["tokens_per_forward"] > s0["tokens_per_forward"]
    assert s1["tokens_per_forward"] > 1.5


def test_spec_prefix_with_drafting(setup):
    """Speculative-prefix admission + drafted continuation, against the
    undrafted spec-prefix engine (temperature 0 => identical accepts)."""
    cfg, params, prompts, keys = setup
    gen = GenerateConfig(max_new_tokens=N, temperature=0.0)
    base, _ = _run(cfg, params, gen, prompts, keys, None)
    vkeys = np.asarray(jax.vmap(lambda i: jax.random.fold_in(
        jax.random.PRNGKey(17), i))(jnp.arange(len(prompts))))

    def spec_reqs(draft):
        eng = make_slot_engine(params, cfg, gen, num_slots=3, prompt_width=P,
                               spec_prefix=True, draft=draft)
        for i, p in enumerate(prompts):
            toks = np.asarray(base[i], np.int32)
            # a *wrong-tail* draft forces mid-sequence rejection so the
            # continuation actually decodes (and drafts)
            half = max(1, len(toks) // 2)
            bad = np.concatenate([toks[:half], (toks[half:] + 1) % V])
            r = Request(request_id=i, prompt=p, key=keys[i],
                        max_new_tokens=N, verify_key=vkeys[i],
                        draft_tokens=bad.astype(np.int32),
                        draft_logprobs=np.zeros(len(bad), np.float32),
                        draft_eos=False,
                        ngram_corpus=[toks])
            eng.submit(r)
        resp = eng.run()
        out = {}
        for i in resp:
            r = resp[i]
            out[i] = (np.concatenate([np.asarray(base[i], np.int32)
                                      [:r.n_accepted], r.tokens]).tolist())
        return out, eng.stats()

    undrafted, _ = spec_reqs(None)
    drafted, s = spec_reqs(DraftConfig(kind="ngram", draft_k=4))
    assert drafted == undrafted
    assert s["tokens_per_forward"] > 1.0
