"""Chaos tests for the fault-tolerant slot server (DESIGN.md §10).

Every recovery arc is driven by *injected*, seeded faults (serving/faults.py)
and asserted exactly: rows untouched by faults stay token-identical to a
fault-free run (per-request PRNG keys make output slot/batch independent),
targeted rows recover through quarantine -> bounded retry -> re-admission,
backpressure sheds resolve to explicit terminal responses, and every event
is visible as a counter in ``stats()``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.engine.generate import GenerateConfig
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.serving import (EngineKilled, FaultEvent, FaultPlan, Request,
                           SlotEngine, seeded_plan)
from repro.serving.request import (FINISH_BUDGET, FINISH_EOS,
                                   FINISH_FULL_REUSE, FINISH_SHED,
                                   FINISH_TIMEOUT)

P, N, V, R = 8, 12, 32, 6
SUCCESS = {FINISH_EOS, FINISH_BUDGET, FINISH_FULL_REUSE}


@pytest.fixture(scope="module")
def setup():
    cfg = ModelConfig(name="t", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=V)
    params = M.init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    prompts = [rng.randint(3, V, rng.randint(3, P + 1)).astype(np.int32)
               for _ in range(R)]
    keys = np.asarray(jax.vmap(lambda i: jax.random.fold_in(
        jax.random.PRNGKey(5), i))(jnp.arange(R)))
    return cfg, params, prompts, keys


def _gen(temperature=1.0):
    return GenerateConfig(max_new_tokens=N, eos_id=V - 1,
                          temperature=temperature)


def _reqs(prompts, keys, **kw):
    return [Request(request_id=i, prompt=p, key=keys[i], max_new_tokens=N,
                    **kw) for i, p in enumerate(prompts)]


def _run(cfg, params, prompts, keys, *, gen=None, slots=2, req_kw=None,
         draft=None, **ekw):
    eng = SlotEngine(params, cfg, gen or _gen(), num_slots=slots,
                     prompt_width=P, chunk_steps=4, draft=draft, **ekw)
    for r in _reqs(prompts, keys, **(req_kw or {})):
        eng.submit(r)
    return eng, eng.run()


@pytest.fixture(scope="module")
def baseline(setup):
    """Fault-free per-request tokens — the identity reference."""
    cfg, params, prompts, keys = setup
    _, resps = _run(cfg, params, prompts, keys, slots=3)
    return {i: resps[i].tokens.copy() for i in resps}


@pytest.fixture(scope="module")
def baseline_greedy(setup):
    cfg, params, prompts, keys = setup
    _, resps = _run(cfg, params, prompts, keys, slots=3,
                    gen=_gen(temperature=0.0))
    return {i: resps[i].tokens.copy() for i in resps}


def test_hardened_clean_run_identity(setup, baseline):
    """The §10 machinery is free on the clean path: guards + deadlines +
    bounded queue + an (empty) plan leave tokens bit-identical and every
    fault counter at zero."""
    cfg, params, prompts, keys = setup
    eng, resps = _run(cfg, params, prompts, keys, faults=FaultPlan(),
                      deadline_steps=10 ** 6, max_queue=64)
    for i in range(R):
        np.testing.assert_array_equal(resps[i].tokens, baseline[i])
        assert resps[i].retries == 0
    st = eng.stats()
    for k, v in st.items():
        if k.startswith("fault_"):
            assert v == 0, (k, v)
    assert st["timeouts"] == 0 and st["shed_requests"] == 0


def test_nan_quarantine_retries_token_identical(setup, baseline):
    """Injected non-finite logits quarantine the row in-chunk; the bounded
    retry regenerates from the request's own PRNG key, so even targeted
    rows end token-identical — and untargeted rows never notice."""
    cfg, params, prompts, keys = setup
    plan = FaultPlan([FaultEvent("nan", at_step=0, request_id=0),
                      FaultEvent("nan", at_step=6, request_id=3)])
    eng, resps = _run(cfg, params, prompts, keys, faults=plan)
    assert sorted(resps) == list(range(R))
    for i in range(R):
        assert resps[i].finish_reason in SUCCESS, (i, resps[i].finish_reason)
        np.testing.assert_array_equal(resps[i].tokens, baseline[i])
    assert resps[0].retries == 1 and resps[3].retries == 1
    st = eng.stats()
    assert st["fault_injected"] == 2
    assert st["fault_nan_events"] == 2
    assert st["fault_quarantines"] == 2
    assert st["quarantined_requests"] == 2
    assert st["retried_requests"] == 2
    assert plan.exhausted()


def test_stall_trips_deadline_and_retries(setup, baseline):
    """A stalled row (phantom slot aging) deterministically blows its
    deadline, is reclaimed, and completes on retry; nobody else times out
    because the deadline clock is per-occupancy."""
    cfg, params, prompts, keys = setup
    plan = FaultPlan([FaultEvent("stall", at_step=0, request_id=0,
                                 count=10 ** 6)])
    eng, resps = _run(cfg, params, prompts, keys, faults=plan,
                      deadline_steps=64)
    for i in range(R):
        assert resps[i].finish_reason in SUCCESS
        np.testing.assert_array_equal(resps[i].tokens, baseline[i])
    assert resps[0].retries == 1
    st = eng.stats()
    assert st["timeouts"] == 1 and st["fault_timeouts"] == 1
    assert st["retried_requests"] == 1
    assert st["fault_quarantines"] == 0


def test_retries_exhausted_fails_with_clean_partial(setup, baseline):
    """max_retries=0: the timed-out request fails out with an explicit
    terminal response whose tokens are a clean prefix of the fault-free
    output (best-effort partial, never garbage)."""
    cfg, params, prompts, keys = setup
    plan = FaultPlan([FaultEvent("stall", at_step=0, request_id=0,
                                 count=10 ** 6)])
    eng, resps = _run(cfg, params, prompts, keys, faults=plan,
                      deadline_steps=64, req_kw={"max_retries": 0})
    r0 = resps[0]
    assert r0.finish_reason == FINISH_TIMEOUT
    assert 0 < r0.length < N
    np.testing.assert_array_equal(r0.tokens, baseline[0][:r0.length])
    for i in range(1, R):
        assert resps[i].finish_reason in SUCCESS
        np.testing.assert_array_equal(resps[i].tokens, baseline[i])
    assert eng.stats()["fault_failed"] == 1


def test_backpressure_reject(setup, baseline):
    """Bounded queue, policy 'reject': newcomers beyond the bound resolve
    immediately as shed; everyone admitted completes untouched."""
    cfg, params, prompts, keys = setup
    eng, resps = _run(cfg, params, prompts, keys, slots=1, max_queue=2,
                      overflow="reject")
    for i in (0, 1):
        assert resps[i].finish_reason in SUCCESS
        np.testing.assert_array_equal(resps[i].tokens, baseline[i])
    for i in range(2, R):
        assert resps[i].finish_reason == FINISH_SHED
        assert resps[i].length == 0
    st = eng.stats()
    assert st["rejected_requests"] == 4 and st["shed_requests"] == 4
    assert st["fault_sheds"] == 4 and st["fault_failed"] == 4
    assert st["completed"] == 2


def test_backpressure_shed_oldest(setup, baseline):
    """Policy 'shed-oldest': the queue head is dropped to admit the
    newcomer — the survivors are the most recent submissions."""
    cfg, params, prompts, keys = setup
    eng, resps = _run(cfg, params, prompts, keys, slots=1, max_queue=2,
                      overflow="shed-oldest")
    for i in (4, 5):
        assert resps[i].finish_reason in SUCCESS
        np.testing.assert_array_equal(resps[i].tokens, baseline[i])
    for i in range(4):
        assert resps[i].finish_reason == FINISH_SHED
    st = eng.stats()
    assert st["shed_requests"] == 4 and st["rejected_requests"] == 0


def test_burst_overflows_bounded_queue(setup, baseline):
    """An arrival burst through the fault plan's request_factory overflows
    the bounded queue mid-run; backpressure sheds the excess and every
    admitted request (base + surviving burst) still completes."""
    cfg, params, prompts, keys = setup

    def factory(i):
        return Request(request_id=100 + i, prompt=prompts[i % R],
                       key=np.asarray(jax.random.fold_in(
                           jax.random.PRNGKey(99), i)),
                       max_new_tokens=N)

    plan = FaultPlan([FaultEvent("burst", at_step=0, count=5)],
                     request_factory=factory)
    gen = _gen()
    eng = SlotEngine(params, cfg, gen, num_slots=2, prompt_width=P,
                     chunk_steps=4, faults=plan, max_queue=4,
                     overflow="reject")
    for r in _reqs(prompts[:2], keys):
        eng.submit(r)
    resps = eng.run()
    for i in (0, 1):                       # base requests rode it out
        assert resps[i].finish_reason in SUCCESS
        np.testing.assert_array_equal(resps[i].tokens, baseline[i])
    burst_ids = [100 + i for i in range(5)]
    shed = [i for i in burst_ids if resps[i].finish_reason == FINISH_SHED]
    served = [i for i in burst_ids if resps[i].finish_reason in SUCCESS]
    # the burst fires at the step-0 boundary, before first admission: the
    # queue still holds both base requests, so 2 of 5 burst requests fit
    assert len(shed) == 3 and len(served) == 2
    st = eng.stats()
    assert st["fault_injected"] == 1
    assert st["shed_requests"] == len(shed)


def test_draft_exception_disables_drafting_not_engine(setup, baseline_greedy):
    """A draft source that raises loses its drafting privilege for that row;
    the request decodes on plain and greedy tokens stay identical for every
    row (drafting is an accelerator, never a semantic)."""
    from repro.drafting import DraftConfig
    cfg, params, prompts, keys = setup
    plan = FaultPlan([FaultEvent("draft_exc", at_step=0, request_id=0),
                      FaultEvent("draft_exc", at_step=0, request_id=4)])
    eng, resps = _run(cfg, params, prompts, keys, gen=_gen(temperature=0.0),
                      draft=DraftConfig(kind="ngram", draft_k=4), faults=plan)
    for i in range(R):
        assert resps[i].finish_reason in SUCCESS
        np.testing.assert_array_equal(resps[i].tokens, baseline_greedy[i])
        assert resps[i].retries == 0
    st = eng.stats()
    assert st["fault_draft_errors"] == 2
    assert st["fault_draft_disabled"] == 2
    assert st["fault_quarantines"] == 0


def test_nan_in_drafted_engine_quarantines_block(setup, baseline_greedy):
    """The host-side non-finite guard on drafted chunks: the poisoned block
    is rolled back, the row quarantined and retried — greedy tokens still
    land identical."""
    from repro.drafting import DraftConfig
    cfg, params, prompts, keys = setup
    plan = FaultPlan([FaultEvent("nan", at_step=0, request_id=1)])
    eng, resps = _run(cfg, params, prompts, keys, gen=_gen(temperature=0.0),
                      draft=DraftConfig(kind="ngram", draft_k=4), faults=plan)
    for i in range(R):
        assert resps[i].finish_reason in SUCCESS
        np.testing.assert_array_equal(resps[i].tokens, baseline_greedy[i])
    assert resps[1].retries == 1
    st = eng.stats()
    assert st["fault_nan_events"] == 1
    assert st["fault_quarantines"] == 1
    assert st["fault_draft_disabled"] == 1     # ladder rung 1 for the row


def test_second_strike_walks_impl_ladder(setup):
    """Two quarantines of the same request step the decode impl down one
    rung (auto -> blocked) — the engine-wide rung 2 after per-row
    degradation was not enough — and the request still completes."""
    cfg, params, prompts, keys = setup
    plan = FaultPlan([FaultEvent("nan", at_step=0, request_id=0),
                      FaultEvent("nan", at_step=12, request_id=0)])
    eng, resps = _run(cfg, params, prompts[:2], keys[:2], faults=plan,
                      req_kw={"max_retries": 2})
    assert resps[0].finish_reason in SUCCESS
    assert resps[0].retries == 2
    assert eng.cfg.decode_impl == "blocked"
    st = eng.stats()
    assert st["fault_impl_fallbacks"] == 1
    assert st["fault_quarantines"] == 2


def test_kill_raises_at_chunk_boundary(setup):
    cfg, params, prompts, keys = setup
    plan = FaultPlan([FaultEvent("kill", at_step=8)])
    gen = _gen()
    eng = SlotEngine(params, cfg, gen, num_slots=2, prompt_width=P,
                     chunk_steps=4, faults=plan)
    for r in _reqs(prompts, keys):
        eng.submit(r)
    with pytest.raises(EngineKilled):
        eng.run()
    assert eng.steps == 8                       # died at the boundary
    assert eng.scheduler.num_active > 0         # mid-flight state to resume
    assert eng.stats()["fault_injected"] == 1


def test_seeded_chaos_plan(setup, baseline):
    """The acceptance scenario: a seeded mixed plan (nan + stall + burst)
    against a hardened engine.  Every non-shed request reaches a successful
    terminal response, untargeted surviving rows are token-identical to the
    fault-free run, and the whole story is visible in stats()."""
    cfg, params, prompts, keys = setup

    def factory(i):
        return Request(request_id=100 + i, prompt=prompts[i % R],
                       key=np.asarray(jax.random.fold_in(
                           jax.random.PRNGKey(99), i)),
                       max_new_tokens=N)

    plan = seeded_plan(0, request_ids=range(R), max_step=12, n_nan=2,
                       n_stall=1, n_burst=1, burst_size=3,
                       request_factory=factory)
    targeted = plan.targeted_requests()
    assert targeted                             # the seed really targets

    gen = _gen()
    # queue bound sized so the 6 upfront submissions + the 3-burst fit:
    # shed-oldest must not drop the fault targets before they reach a slot
    # (backpressure-under-overflow has its own dedicated tests above)
    eng = SlotEngine(params, cfg, gen, num_slots=2, prompt_width=P,
                     chunk_steps=4, faults=plan, deadline_steps=64,
                     max_queue=9, overflow="shed-oldest")
    for r in _reqs(prompts, keys, max_retries=3):
        eng.submit(r)
    resps = eng.run()

    # burst baseline: same requests through a clean engine
    clean = SlotEngine(params, cfg, gen, num_slots=2, prompt_width=P,
                       chunk_steps=4)
    for i in range(3):
        clean.submit(factory(i))
    burst_base = {i: r.tokens.copy() for i, r in clean.run().items()}

    all_ids = set(range(R)) | {100 + i for i in range(3)}
    assert set(resps) == all_ids                # every request resolved
    shed = {i for i in resps if resps[i].finish_reason == FINISH_SHED}
    for i in all_ids - shed:
        assert resps[i].finish_reason in SUCCESS, (i, resps[i].finish_reason)
        if i not in targeted:
            ref = baseline[i] if i < 100 else burst_base[i]
            np.testing.assert_array_equal(resps[i].tokens, ref)
    assert plan.exhausted()
    st = eng.stats()
    assert st["fault_injected"] == len(plan.events)
    assert st["fault_nan_events"] + st["timeouts"] > 0
    assert st["retried_requests"] > 0
    assert eng.scheduler.idle


def test_retry_backoff_holds_then_completes(setup, baseline):
    """§12 backoff adoption: with a BackoffConfig, a reclaimed request is
    held out of the queue until its exponential-backoff due step, then
    re-admitted through the same speculative-prefix retry path — output
    stays token-identical to an immediate-retry run."""
    from repro.core.backoff import BackoffConfig
    cfg, params, prompts, keys = setup
    plan = FaultPlan([FaultEvent("stall", at_step=0, request_id=0,
                                 count=10 ** 6)])
    eng, resps = _run(cfg, params, prompts, keys, faults=plan,
                      deadline_steps=64,
                      retry_backoff=BackoffConfig(base=8.0, factor=2.0,
                                                  max_delay=64.0))
    for i in range(R):
        assert resps[i].finish_reason in SUCCESS
        np.testing.assert_array_equal(resps[i].tokens, baseline[i])
    assert resps[0].retries == 1
    assert eng.stats()["retried_requests"] == 1
    assert not eng._retry_hold                  # drained by completion


def test_retry_backoff_hold_rides_kill_resume(setup):
    """A held retry is in-flight work: it must survive state_dict /
    load_state_dict, and default-config snapshots must not grow a key."""
    from repro.core.backoff import BackoffConfig
    cfg, params, prompts, keys = setup
    bo = BackoffConfig(base=8.0, factor=2.0, max_delay=64.0)
    plan = FaultPlan([FaultEvent("stall", at_step=0, request_id=0,
                                 count=10 ** 6)])
    eng = SlotEngine(params, cfg, _gen(), num_slots=2, prompt_width=P,
                     chunk_steps=4, faults=plan, deadline_steps=64,
                     retry_backoff=bo)
    for r in _reqs(prompts, keys):
        eng.submit(r)
    # run until the stalled request has been reclaimed into the hold
    while not eng._retry_hold:
        eng.run(max_chunks=1)
    st = eng.state_dict()
    assert "retry_hold" in st and len(st["retry_hold"]) == 1

    eng2 = SlotEngine(params, cfg, _gen(), num_slots=2, prompt_width=P,
                      chunk_steps=4, deadline_steps=64, retry_backoff=bo)
    eng2.load_state_dict(st)
    assert len(eng2._retry_hold) == 1
    assert eng2._retry_hold[0][0] == eng._retry_hold[0][0]
    r1, r2 = eng.run(), eng2.run()
    for i in r1:
        np.testing.assert_array_equal(r1[i].tokens, r2[i].tokens)
    # an engine with no holds keeps the pre-§12 snapshot layout
    eng3, _ = _run(cfg, params, prompts, keys, slots=3)
    assert "retry_hold" not in eng3.state_dict()
