"""Exact kill-and-resume (DESIGN.md §10): an engine killed mid-batch,
snapshotted through checkpoint/io and restored into a freshly constructed
engine produces token-identical output to an uninterrupted run — across
vanilla sampling, speculative-prefix admission and the §9 drafted engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.io import load_server_state, save_server_state
from repro.engine.generate import GenerateConfig
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.serving import (EngineKilled, FaultEvent, FaultPlan, Request,
                           SlotEngine)

P, N, V, R = 8, 12, 32, 6


@pytest.fixture(scope="module")
def setup():
    cfg = ModelConfig(name="t", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=V)
    params = M.init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(1)
    prompts = [rng.randint(3, V, rng.randint(3, P + 1)).astype(np.int32)
               for _ in range(R)]
    keys = np.asarray(jax.vmap(lambda i: jax.random.fold_in(
        jax.random.PRNGKey(5), i))(jnp.arange(R)))
    vkeys = np.asarray(jax.vmap(lambda i: jax.random.fold_in(
        jax.random.PRNGKey(17), i))(jnp.arange(R)))
    return cfg, params, prompts, keys, vkeys


def _gen(temperature=1.0):
    return GenerateConfig(max_new_tokens=N, eos_id=V - 1,
                          temperature=temperature)


def _make(cfg, params, gen, **kw):
    return SlotEngine(params, cfg, gen, num_slots=2, prompt_width=P,
                      chunk_steps=4, **kw)


def _submit_all(eng, reqs):
    for r in reqs:
        eng.submit(r)


def _assert_identical(resumed, ref):
    assert sorted(resumed) == sorted(ref)
    for i in ref:
        a, b = resumed[i], ref[i]
        assert a.finish_reason == b.finish_reason, i
        assert a.length == b.length and a.n_accepted == b.n_accepted, i
        np.testing.assert_array_equal(a.tokens, b.tokens)
        np.testing.assert_allclose(a.logprobs, b.logprobs, atol=1e-6)


def _kill_resume_roundtrip(tmp_path, mk_engine, mk_reqs, kill_at=8):
    """Run to completion; rerun with an injected kill + save/load; compare."""
    ref_eng = mk_engine()
    _submit_all(ref_eng, mk_reqs())
    ref = ref_eng.run()

    killed = mk_engine(faults=FaultPlan([FaultEvent("kill", at_step=kill_at)]))
    _submit_all(killed, mk_reqs())
    with pytest.raises(EngineKilled):
        killed.run()
    assert killed.scheduler.num_active > 0      # genuinely mid-batch
    assert len(killed.responses) < R
    save_server_state(str(tmp_path / "snap"), killed,
                      metadata={"requests": R})

    resumed = mk_engine()
    meta = load_server_state(str(tmp_path / "snap"), resumed)
    assert meta["kind"] == "server_state" and meta["requests"] == R
    resps = resumed.run()
    _assert_identical(resps, ref)
    st = resumed.stats()
    assert st["completed"] == len([r for r in ref.values()
                                   if r.finish_reason != "shed"])
    return ref_eng, resumed


def test_kill_resume_vanilla(setup, tmp_path):
    cfg, params, prompts, keys, _ = setup
    gen = _gen()

    def reqs():
        return [Request(request_id=i, prompt=prompts[i], key=keys[i],
                        max_new_tokens=N) for i in range(R)]

    # step 12: requests 0/1 already completed, 2/3 mid-decode, 4/5 queued —
    # the snapshot carries responses, in-flight slots AND a queue at once
    _kill_resume_roundtrip(tmp_path, lambda **kw: _make(cfg, params, gen, **kw),
                           reqs, kill_at=12)


def test_kill_resume_spec_prefix(setup, tmp_path):
    """Mid-verification serving state (accepted prefixes, prefix logprobs,
    verify keys of still-queued requests) round-trips exactly."""
    cfg, params, prompts, keys, vkeys = setup
    gen = _gen()
    base_eng = _make(cfg, params, gen)
    _submit_all(base_eng, [Request(request_id=i, prompt=prompts[i],
                                   key=keys[i], max_new_tokens=N)
                           for i in range(R)])
    base = base_eng.run()

    def reqs():
        out = []
        for i in range(R):
            toks = np.asarray(base[i].tokens, np.int32)
            half = max(1, len(toks) // 2)
            bad = np.concatenate([toks[:half], (toks[half:] + 1) % V])
            out.append(Request(
                request_id=i, prompt=prompts[i], key=keys[i],
                max_new_tokens=N, verify_key=vkeys[i],
                draft_tokens=bad.astype(np.int32),
                draft_logprobs=np.asarray(base[i].logprobs, np.float32),
                draft_eos=False))
        return out

    ref_eng, resumed = _kill_resume_roundtrip(
        tmp_path, lambda **kw: _make(cfg, params, gen, spec_prefix=True, **kw),
        reqs, kill_at=4)
    # the run actually exercised speculative-prefix admission
    assert sum(r.n_accepted for r in resumed.responses.values()) > 0


def test_kill_resume_drafted(setup, tmp_path):
    """§9 draft state (controller EMAs, n-gram streams + corpora) resumes
    bit-exactly: greedy drafted output is identical to uninterrupted."""
    from repro.drafting import DraftConfig
    cfg, params, prompts, keys, _ = setup
    gen = _gen(temperature=0.0)

    def reqs():
        return [Request(request_id=i, prompt=prompts[i], key=keys[i],
                        max_new_tokens=N,
                        ngram_corpus=[prompts[(i + 1) % R]])
                for i in range(R)]

    ref_eng, resumed = _kill_resume_roundtrip(
        tmp_path,
        lambda **kw: _make(cfg, params, gen,
                           draft=DraftConfig(kind="ngram", draft_k=4), **kw),
        reqs, kill_at=4)
    assert resumed.stats()["draft_proposed"] > 0


def test_kill_resume_preserves_recovery_state(setup, tmp_path):
    """A kill landing between a quarantine and the retry's completion: the
    retry draft, nan strike count and fault counters all survive the
    round-trip and the retried request still completes."""
    cfg, params, prompts, keys, _ = setup
    gen = _gen()

    def reqs():
        return [Request(request_id=i, prompt=prompts[i], key=keys[i],
                        max_new_tokens=N) for i in range(R)]

    ref_eng = _make(cfg, params, gen,
                    faults=FaultPlan([FaultEvent("nan", at_step=0,
                                                 request_id=0)]))
    _submit_all(ref_eng, reqs())
    ref = ref_eng.run()

    killed = _make(cfg, params, gen,
                   faults=FaultPlan([FaultEvent("nan", at_step=0,
                                                request_id=0),
                                     FaultEvent("kill", at_step=8)]))
    _submit_all(killed, reqs())
    with pytest.raises(EngineKilled):
        killed.run()
    assert killed.fault_stats.nan_events == 1   # quarantine before the kill
    save_server_state(str(tmp_path / "snap2"), killed)

    resumed = _make(cfg, params, gen)
    load_server_state(str(tmp_path / "snap2"), resumed)
    resps = resumed.run()
    _assert_identical(resps, ref)
    assert resps[0].retries == 1
    st = resumed.stats()
    assert st["fault_nan_events"] == 1 and st["retried_requests"] == 1


def test_state_dict_is_all_arrays(setup):
    """The snapshot is a pure array pytree — the contract that lets the
    generic atomic pytree writer carry it."""
    cfg, params, prompts, keys, _ = setup
    eng = _make(cfg, params, _gen())
    _submit_all(eng, [Request(request_id=i, prompt=prompts[i], key=keys[i],
                              max_new_tokens=N) for i in range(R)])
    eng.run(max_chunks=1)
    leaves = jax.tree.leaves(eng.state_dict())
    assert leaves
    for leaf in leaves:
        assert isinstance(leaf, (np.ndarray, np.generic, jnp.ndarray)), \
            type(leaf)
