"""Paged slot engine (DESIGN.md §13): token identity with the dense engine,
one physical prompt copy per GRPO group (CoW sharing), boundary-block forks,
pool-pressure admission capping / load shedding, and exact kill-and-resume
carrying the allocator + block tables + group registry."""
import copy

import jax
import numpy as np
import pytest

from repro.checkpoint.io import load_server_state, save_server_state
from repro.engine.generate import GenerateConfig
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.serving import (EngineKilled, FaultEvent, FaultPlan,
                           PagedSlotEngine, Request, SlotEngine,
                           make_slot_engine)
from repro.serving.request import FINISH_SHED

P, N, V = 9, 7, 32                 # P % block_size != 0: boundary block CoW
BS = 4
G, S = 3, 2                        # GRPO groups x siblings


@pytest.fixture(scope="module")
def setup():
    cfg = ModelConfig(name="t", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=V)
    params = M.init_lm(jax.random.PRNGKey(0), cfg)
    return cfg, cfg.replace(cache_layout="paged", kv_block_size=BS), params


def _group_requests(seed=0, groups=G, sib=S, max_new=N):
    """`groups` GRPO groups of `sib` siblings sharing a prompt."""
    rng = np.random.RandomState(seed)
    reqs, rid = [], 0
    for g in range(groups):
        prompt = rng.randint(3, V, size=rng.randint(4, P + 1)).astype(np.int32)
        for _ in range(sib):
            key = np.asarray(jax.random.PRNGKey(1000 + rid), np.uint32)
            reqs.append(Request(request_id=rid, prompt=prompt.copy(), key=key,
                                max_new_tokens=max_new, group_id=g))
            rid += 1
    return reqs


def _run(params, cfg, gen, reqs, **kw):
    eng = make_slot_engine(params, cfg, gen, num_slots=kw.pop("num_slots", 4),
                           prompt_width=P, **kw)
    for r in reqs:
        eng.submit(copy.deepcopy(r))
    return eng, eng.run()


def _assert_identical(a, b):
    assert sorted(a) == sorted(b)
    for i in a:
        assert a[i].finish_reason == b[i].finish_reason, i
        assert a[i].length == b[i].length, i
        np.testing.assert_array_equal(a[i].tokens, b[i].tokens)
        np.testing.assert_array_equal(np.asarray(a[i].logprobs),
                                      np.asarray(b[i].logprobs))


def test_paged_engine_matches_dense_with_grpo_sharing(setup):
    """Bit-identical tokens AND logprobs vs the dense engine while CoW
    prompt sharing is active (more requests than slots: admission waves)."""
    cfg_d, cfg_p, params = setup
    gen = GenerateConfig(max_new_tokens=N, temperature=0.7)
    reqs = _group_requests()
    eng_d, dense = _run(params, cfg_d, gen, reqs)
    eng_p, paged = _run(params, cfg_p, gen, reqs)
    assert isinstance(eng_d, SlotEngine) and not isinstance(eng_d,
                                                            PagedSlotEngine)
    assert isinstance(eng_p, PagedSlotEngine)
    _assert_identical(paged, dense)
    st = eng_p.allocator.stats()
    assert st["shared_prompt_bytes_saved"] > 0
    # every follower forks exactly the prompt boundary block, once
    assert st["cow_forks"] == G * (S - 1)
    assert st["blocks_in_use"] == 0          # fully drained
    eng_p.allocator.check()
    reg = eng_p.stats()
    assert reg["paged_cow_forks"] == st["cow_forks"]
    assert reg["paged_shared_prompt_bytes_saved"] == \
        st["shared_prompt_bytes_saved"]
    assert reg["paged_blocks_in_use"] == 0.0
    assert reg["paged_peak_blocks_in_use"] > 0


def test_one_physical_prompt_copy_per_group(setup):
    """§13 acceptance: after admission (before any decode chunk) all G
    siblings of a group address the SAME physical prompt blocks — exactly
    one prompt copy per group in the pool — and fork only on first write."""
    _, cfg_p, params = setup
    gen = GenerateConfig(max_new_tokens=N, temperature=0.7)
    eng = make_slot_engine(params, cfg_p, gen, num_slots=G * S,
                           prompt_width=P)
    for r in _group_requests():
        eng.submit(copy.deepcopy(r))
    eng._admit()                             # all slots admit in one wave
    nb, pb = eng.nb, eng._pb
    assert pb == -(-P // BS)
    by_gid = {}
    for slot, req in eng.scheduler.active.items():
        row = eng._slot_blocks[slot]
        assert row is not None and len(row) == nb
        by_gid.setdefault(req.group_id, []).append(row)
    assert sorted(by_gid) == list(range(G))
    for gid, rows in by_gid.items():
        assert len(rows) == S
        for row in rows[1:]:                 # shared prompt prefix, incl.
            assert row[:pb] == rows[0][:pb]  # the boundary block
        # continuations are private from the start
        tails = [b for row in rows for b in row[pb:]]
        assert len(set(tails)) == len(tails)
    # pool holds ONE prompt copy + S continuations per group (no forks yet)
    assert eng.allocator.cow_forks == 0
    assert eng.allocator.blocks_in_use == G * (pb + S * (nb - pb))
    # device tables mirror the host bookkeeping
    tab = np.asarray(eng.caches[0]["self"]["table"][0])
    for slot in eng.scheduler.active:
        np.testing.assert_array_equal(tab[slot], eng._slot_blocks[slot])
    # first chunk CoW-forks each follower's boundary block exactly once
    eng._run_chunk()
    assert eng.allocator.cow_forks == G * (S - 1)
    for gid, _ in by_gid.items():
        rows = [eng._slot_blocks[s] for s, r in eng.scheduler.active.items()
                if r.group_id == gid]
        bnd = {row[pb - 1] for row in rows}
        assert len(bnd) == S                 # boundary now private per row
        shared = {tuple(row[:pb - 1]) for row in rows}
        assert len(shared) == 1              # full prompt blocks still shared
    eng.run()                                # drain cleanly
    assert eng.allocator.blocks_in_use == 0
    eng.allocator.check()


def test_admission_pressure_queues_in_order(setup):
    """A pool sized for one row at a time: requests wait QUEUED under
    pressure and admit strictly in order as completions free blocks —
    nothing is shed, output identical to an unconstrained pool."""
    _, cfg_p, params = setup
    gen = GenerateConfig(max_new_tokens=N, temperature=0.7)
    reqs = _group_requests(seed=3, groups=3, sib=1)   # distinct groups
    eng_ref, ref = _run(params, cfg_p, gen, reqs, num_slots=2)
    nb = eng_ref.nb
    eng, out = _run(params, cfg_p, gen, reqs, num_slots=2,
                    kv_pool_blocks=1 + nb)            # sink + ONE row
    _assert_identical(out, ref)
    assert all(out[i].finish_reason != FINISH_SHED for i in out)
    assert eng.allocator.alloc_failures == 0          # capped, never failed
    assert eng.allocator.peak_blocks_in_use <= nb
    st = eng.scheduler.stats()
    assert st["completed"] == len(reqs)


def test_pool_too_small_sheds_instead_of_livelocking(setup):
    """A request that cannot be tabled even on an EMPTY batch is shed with
    FINISH_SHED (slot=-1) instead of waiting forever."""
    _, cfg_p, params = setup
    gen = GenerateConfig(max_new_tokens=N, temperature=0.7)
    reqs = _group_requests(seed=4, groups=2, sib=1)
    probe = PagedSlotEngine(params, cfg_p, gen, num_slots=2, prompt_width=P)
    nb = probe.nb
    eng, out = _run(params, cfg_p, gen, reqs, num_slots=2,
                    kv_pool_blocks=nb)                # sink + nb-1: never fits
    assert sorted(out) == [0, 1]
    for i in out:
        assert out[i].finish_reason == FINISH_SHED
        assert out[i].slot == -1 and out[i].length == 0
    assert eng.allocator.alloc_failures == 2
    assert eng.stats()["paged_alloc_failures"] == 2
    assert eng.allocator.blocks_in_use == 0
    eng.allocator.check()


def test_kill_resume_paged(setup, tmp_path):
    """§10 x §13: a paged engine killed mid-batch (allocator, block tables,
    group registry and seed logits all in ``state_dict()['paged']``) resumes
    into token-identical output — which also still matches dense."""
    cfg_d, cfg_p, params = setup
    gen = GenerateConfig(max_new_tokens=N, temperature=0.7)
    # 3 siblings over 2 slots: a group always straddles admission waves, so
    # the kill lands with a LIVE group registration in the snapshot
    reqs = _group_requests(seed=7, groups=2, sib=3)

    def mk(**kw):
        return make_slot_engine(params, cfg_p, gen, num_slots=2,
                                prompt_width=P, chunk_steps=4, **kw)

    _, dense = _run(params, cfg_d, gen, reqs, num_slots=2, chunk_steps=4)
    ref_eng = mk()
    for r in reqs:
        ref_eng.submit(copy.deepcopy(r))
    ref = ref_eng.run()
    _assert_identical(ref, dense)

    killed = mk(faults=FaultPlan([FaultEvent("kill", at_step=6)]))
    for r in reqs:
        killed.submit(copy.deepcopy(r))
    with pytest.raises(EngineKilled):
        killed.run()
    assert killed.scheduler.num_active > 0            # genuinely mid-batch
    assert killed._groups                             # registry in flight
    assert any(b is not None for b in killed._slot_blocks)
    save_server_state(str(tmp_path / "snap"), killed)

    resumed = mk()
    load_server_state(str(tmp_path / "snap"), resumed)
    assert resumed.allocator.blocks_in_use == \
        killed.allocator.blocks_in_use
    resps = resumed.run()
    _assert_identical(resps, ref)
    assert resumed.allocator.blocks_in_use == 0
    resumed.allocator.check()
    # the resumed run still exercised sharing (followers after the kill)
    assert resumed.allocator.shared_prompt_bytes_saved > 0


def test_group_registry_gc(setup):
    """Registrations live exactly as long as a pending sibling can still
    share them; dropping one returns the prompt copy's blocks to the pool."""
    _, cfg_p, params = setup
    gen = GenerateConfig(max_new_tokens=N, temperature=0.7)
    eng = make_slot_engine(params, cfg_p, gen, num_slots=3, prompt_width=P)
    for r in _group_requests():
        eng.submit(copy.deepcopy(r))
    # wave 1 admits g0(both siblings) + g1's leader; g1's sibling is still
    # queued so gid 1 stays registered, gid 0 (fully admitted) is gc'd, and
    # gid 2 (nothing admitted yet) was never registered
    eng._admit()
    assert sorted(eng._groups) == [1]
    eng.run()
    assert eng._groups == {}                          # gc'd at drain
    assert eng.allocator.blocks_in_use == 0


def test_mixed_grouped_and_ungrouped(setup):
    """group_id=None requests interleave with GRPO groups untouched by the
    sharing machinery and stay identical to dense."""
    cfg_d, cfg_p, params = setup
    gen = GenerateConfig(max_new_tokens=N, temperature=0.7)
    reqs = _group_requests(seed=5, groups=2, sib=2)
    rng = np.random.RandomState(9)
    for j in range(2):
        prompt = rng.randint(3, V, size=rng.randint(4, P + 1)).astype(np.int32)
        reqs.append(Request(request_id=100 + j, prompt=prompt,
                            key=np.asarray(jax.random.PRNGKey(77 + j),
                                           np.uint32),
                            max_new_tokens=N))
    _, dense = _run(params, cfg_d, gen, reqs, num_slots=3)
    eng, paged = _run(params, cfg_p, gen, reqs, num_slots=3)
    _assert_identical(paged, dense)
    assert eng.allocator.blocks_in_use == 0
