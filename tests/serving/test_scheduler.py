"""SlotScheduler semantics: FIFO admission over the free-list, request
lifecycle states, occupancy/latency metrics, and RolloutCache LRU pressure
feeding the admission queue."""
import numpy as np
import pytest

from repro.serving.request import (DECODING, DONE, PREFILLING, QUEUED,
                                   Request)
from repro.serving.scheduler import SlotScheduler


def _req(i, budget=4):
    return Request(request_id=i, prompt=np.array([1, 2, 3], np.int32),
                   key=np.zeros(2, np.uint32), max_new_tokens=budget)


def test_fifo_admission_order():
    s = SlotScheduler(2)
    for i in range(5):
        s.submit(_req(i))
    group = s.reserve()
    assert [r.request_id for _, r in group] == [0, 1]      # FIFO
    assert s.pending == 3
    assert all(r.state == PREFILLING for _, r in group)
    assert len({slot for slot, _ in group}) == 2           # distinct slots


def test_lifecycle_states_and_free_list():
    s = SlotScheduler(1)
    s.submit(_req(0))
    s.submit(_req(1))
    (slot, req), = s.reserve()
    assert req.state == PREFILLING and req.request_id == 0
    s.activate(slot)
    assert req.state == DECODING
    assert not s.reserve()                                 # no free slot
    done = s.complete(slot)
    assert done.state == DONE and done is req
    (slot2, req2), = s.reserve()                           # backfill
    assert slot2 == slot and req2.request_id == 1


def test_reserve_empty_queue_returns_nothing():
    s = SlotScheduler(3)
    assert s.reserve() == []
    assert s.idle


def test_occupancy_and_counters():
    s = SlotScheduler(4)
    for i in range(2):
        s.submit(_req(i))
    group = s.reserve()
    for slot, _ in group:
        s.activate(slot)
    s.tick(busy_slots=2, steps=10)
    for slot, _ in group:
        s.complete(slot)
    st = s.stats()
    assert st["submitted"] == st["admitted"] == st["completed"] == 2
    assert st["occupancy"] == pytest.approx(20 / 40)
    assert st["pending"] == 0


def test_queue_wait_accounting():
    s = SlotScheduler(1)
    s.submit(_req(0), now=0.0)
    (slot, _), = s.reserve(now=2.0)
    s.activate(slot)
    s.complete(slot, now=5.0)
    st = s.stats()
    assert st["mean_queue_wait"] == pytest.approx(2.0)
    assert st["mean_serve_time"] == pytest.approx(3.0)
