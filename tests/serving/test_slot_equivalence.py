"""Slot-scheduler correctness: for per-request PRNG keys, tokens produced
through the continuous-batching engine are identical to fixed-batch
``generate`` / one-pass ``rollout`` — including speculative-prefix admission
and the cache_slot_write admission path (ISSUE 2 acceptance criterion)."""
import copy
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import RolloutCache, SpecConfig, rollout
from repro.engine.generate import GenerateConfig, generate, positions_from_mask
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.serving import Request, SlotEngine

B, P, N = 6, 8, 12


@pytest.fixture(scope="module")
def setup():
    cfg = ModelConfig(name="t", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=32)
    params_a = M.init_lm(jax.random.PRNGKey(0), cfg)
    params_b = M.init_lm(jax.random.PRNGKey(42), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, P), 3, 32)
    mask = np.ones((B, P), bool)
    mask[0, :3] = False                    # mixed prompt lengths
    mask[3, :2] = False
    prompt = jnp.where(jnp.asarray(mask), prompt, 0)
    return cfg, params_a, params_b, prompt, jnp.asarray(mask)


def _row_keys(seed, n=B):
    return jax.vmap(lambda i: jax.random.fold_in(jax.random.PRNGKey(seed), i)
                    )(jnp.arange(n))


def test_slot_engine_matches_fixed_batch_generate(setup):
    """2 slots drain 6 requests with long-tailed budgets; every request's
    tokens/logprobs/length equal the fixed-batch generate row."""
    cfg, params, _, prompt, mask = setup
    gen = GenerateConfig(max_new_tokens=N)
    keys = _row_keys(7)
    budget = jnp.array([N, 3, 7, N, 1, 5], jnp.int32)
    ref = generate(params, cfg, gen, prompt, mask, keys, row_budget=budget)

    eng = SlotEngine(params, cfg, gen, num_slots=2, prompt_width=P,
                     chunk_steps=4)
    kn, pn, mn = np.asarray(keys), np.asarray(prompt), np.asarray(mask)
    for i in range(B):
        pl = int(mn[i].sum())
        eng.submit(Request(request_id=i, prompt=pn[i, P - pl:], key=kn[i],
                           max_new_tokens=int(budget[i])))
    resps = eng.run()
    for i in range(B):
        L = int(ref["length"][i])
        assert resps[i].length == L
        np.testing.assert_array_equal(resps[i].tokens,
                                      np.asarray(ref["tokens"])[i, :L])
        np.testing.assert_allclose(resps[i].logprobs,
                                   np.asarray(ref["logprobs"])[i, :L],
                                   atol=1e-5, rtol=1e-5)
    st = eng.stats()
    assert st["completed"] == B and st["pending"] == 0
    assert st["generated_tokens"] == float(np.asarray(ref["length"]).sum())


def _seeded_cache(cfg, params, prompt, mask):
    cache = RolloutCache()
    spec = SpecConfig(variant="spec", verify_impl="ref", one_pass="off")
    gen = GenerateConfig(max_new_tokens=N)
    rollout(params, cfg, gen, spec, prompt, mask, list(range(B)), cache,
            jax.random.PRNGKey(0), 0)
    return cache


@pytest.mark.parametrize("variant", ["spec", "delayed"])
def test_backfill_slots_matches_fixed_batch_rollout(setup, variant):
    """rollout(spec.backfill='slots') == fixed-batch one-pass rollout under
    the same per-request keys: responses, lengths, behaviour log-probs,
    reuse metrics and the refreshed cache all agree."""
    cfg, params_a, params_b, prompt, mask = setup
    gen = GenerateConfig(max_new_tokens=N)
    ids = list(range(B))
    cache1 = _seeded_cache(cfg, params_a, prompt, mask)
    if variant == "delayed":
        rollout(params_a, cfg, gen,
                SpecConfig(variant="spec", verify_impl="ref", one_pass="off"),
                prompt, mask, ids, cache1, jax.random.PRNGKey(5), 1)
    cache2 = copy.deepcopy(cache1)

    keys = _row_keys(9)
    fixed = rollout(params_b, cfg, gen,
                    SpecConfig(variant=variant, verify_impl="ref",
                               one_pass="on", compact_impl="ref"),
                    prompt, mask, ids, cache1, keys, 2)
    slots = rollout(params_b, cfg, gen,
                    SpecConfig(variant=variant, verify_impl="ref",
                               one_pass="on", compact_impl="ref",
                               backfill="slots", backfill_slots=2),
                    prompt, mask, ids, cache2, keys, 2)

    np.testing.assert_array_equal(slots.response, fixed.response)
    np.testing.assert_array_equal(slots.length, fixed.length)
    np.testing.assert_array_equal(slots.response_mask, fixed.response_mask)
    np.testing.assert_allclose(slots.behaviour_logprobs,
                               fixed.behaviour_logprobs, atol=1e-5, rtol=1e-5)
    assert slots.metrics["n_reused"] == fixed.metrics["n_reused"]
    assert slots.metrics["n_generated"] == fixed.metrics["n_generated"]
    assert slots.metrics["n_reused"] > 0          # non-trivial comparison
    assert slots.metrics["backfill_slots"] == 2.0
    for i in ids:                                 # immediate cache refresh
        np.testing.assert_array_equal(cache1.get(i).tokens,
                                      cache2.get(i).tokens)


def test_backfill_slots_vanilla_cold_start(setup):
    """Cold start (no drafts): slots mode matches the vanilla rollout path."""
    cfg, params, _, prompt, mask = setup
    gen = GenerateConfig(max_new_tokens=N)
    ids = list(range(B))
    keys = _row_keys(11)
    fixed = rollout(params, cfg, gen, SpecConfig(variant="spec"),
                    prompt, mask, ids, RolloutCache(), keys, 0)
    slots = rollout(params, cfg, gen,
                    SpecConfig(variant="spec", backfill="slots",
                               backfill_slots=3),
                    prompt, mask, ids, RolloutCache(), keys, 0)
    np.testing.assert_array_equal(slots.response, fixed.response)
    np.testing.assert_array_equal(slots.length, fixed.length)
    assert slots.metrics["one_pass"] == 0.0


def test_spec_prefix_admission_with_interpret_kernels(setup):
    """The Pallas admission kernels (interpret mode) on the real slot path:
    cache_slot_write + cache_gather produce the same responses as ref."""
    cfg, params_a, params_b, prompt, mask = setup
    gen = GenerateConfig(max_new_tokens=N)
    ids = list(range(B))
    cache1 = _seeded_cache(cfg, params_a, prompt, mask)
    cache2 = copy.deepcopy(cache1)
    keys = _row_keys(13)
    base = SpecConfig(variant="spec", verify_impl="ref", one_pass="on",
                      backfill="slots", backfill_slots=2)
    ref = rollout(params_b, cfg, gen, replace(base, compact_impl="ref"),
                  prompt, mask, ids, cache1, keys, 1)
    # interpret-mode compaction; slot writes go through the kernel wrapper
    ker = rollout(params_b, cfg, gen, replace(base, compact_impl="interpret"),
                  prompt, mask, ids, cache2, keys, 1)
    np.testing.assert_array_equal(ker.response, ref.response)
    np.testing.assert_array_equal(ker.length, ref.length)


def test_write_cache_slots_exact(setup):
    """write_cache_slots: admitted rows equal the source caches leaf-for-leaf,
    untouched slots bit-identical to the old cache."""
    cfg, params, _, prompt, mask = setup
    caches_a = M.init_cache(cfg, 4, P + 4)
    logits, caches_b = M.prefill(params, cfg, prompt[:2],
                                 positions_from_mask(mask[:2]),
                                 M.init_cache(cfg, 2, P + 4))
    slots = jnp.array([2, 0], jnp.int32)
    out = M.write_cache_slots(cfg, caches_a, caches_b, slots, impl="ref")
    for run_out, run_a, run_b in zip(out, caches_a, caches_b):
        for name in run_out["self"]:
            o = np.asarray(run_out["self"][name])
            a = np.asarray(run_a["self"][name])
            b = np.asarray(run_b["self"][name])
            np.testing.assert_array_equal(o[:, 2], b[:, 0])
            np.testing.assert_array_equal(o[:, 0], b[:, 1])
            np.testing.assert_array_equal(o[:, 1], a[:, 1])
            np.testing.assert_array_equal(o[:, 3], a[:, 3])


def test_arrival_stream_and_states(setup):
    """Requests arriving mid-run are served; lifecycle reaches DONE with a
    finish reason; idle fast-forward does not deadlock."""
    from repro.serving.request import DONE
    cfg, params, _, prompt, mask = setup
    gen = GenerateConfig(max_new_tokens=N, eos_id=31)   # rare eos
    eng = SlotEngine(params, cfg, gen, num_slots=2, prompt_width=P,
                     chunk_steps=4)
    kn, pn, mn = np.asarray(_row_keys(15)), np.asarray(prompt), np.asarray(mask)
    reqs = []
    for i in range(4):
        pl = int(mn[i].sum())
        reqs.append(Request(request_id=i, prompt=pn[i, P - pl:], key=kn[i],
                            max_new_tokens=4 if i % 2 else N))
    # two up front, one mid-run, one far beyond the natural drain point
    eng.submit(reqs[0])
    eng.submit(reqs[1])
    resps = eng.run(arrivals=[(4, reqs[2]), (10 ** 4, reqs[3])])
    assert sorted(resps) == [0, 1, 2, 3]
    assert all(r.state == DONE for r in reqs)
    assert {resps[i].finish_reason for i in range(4)} <= {"eos", "budget"}
    assert resps[3].length > 0
