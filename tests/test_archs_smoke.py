"""Per-architecture smoke tests (deliverable f).

For every assigned architecture: instantiate the REDUCED variant of the same
family (<=2 layers, d_model<=512, <=4 experts — plus one full hybrid period
for jamba) and run one forward pass, one RL train step and one serve
(decode) step on CPU, asserting output shapes and no NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import model as M
from repro.optim import adamw

ARCHS = sorted(ARCH_IDS)


def _inputs(cfg, B=2, T=12, key=0):
    tokens = jax.random.randint(jax.random.PRNGKey(key), (B, T), 3,
                                cfg.vocab_size)
    # row 0 left-padded by 3
    positions = jnp.stack([
        jnp.concatenate([jnp.full((3,), -1, jnp.int32),
                         jnp.arange(T - 3, dtype=jnp.int32)]),
        jnp.arange(T, dtype=jnp.int32)])
    tokens = jnp.where(positions >= 0, tokens, 0)
    return tokens, positions


def _extras(params, cfg, B=2):
    out = {}
    if cfg.encoder_layers:
        frames = jax.random.normal(jax.random.PRNGKey(9),
                                   (B, cfg.encoder_frames, cfg.d_model))
        enc, pos = M.encode(params, cfg, frames)
        out = {"encoder_out": enc, "encoder_positions": pos}
    return out


@pytest.fixture(scope="module")
def smoke_models():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_config(arch).reduced()
            params = M.init_lm(jax.random.PRNGKey(0), cfg)
            cache[arch] = (cfg, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nans(arch, smoke_models):
    cfg, params = smoke_models(arch)
    tokens, positions = _inputs(cfg)
    extras = _extras(params, cfg)
    prefix = None
    if cfg.num_prefix_embeddings:
        P = cfg.num_prefix_embeddings
        prefix = jax.random.normal(jax.random.PRNGKey(4), (2, P, cfg.d_model))
        vis = jnp.broadcast_to(jnp.arange(P, dtype=jnp.int32), (2, P))
        positions_full = jnp.concatenate(
            [vis, jnp.where(positions >= 0, positions + P, -1)], axis=1)
        logits, aux = M.forward(params, cfg, tokens, positions_full,
                                prefix_embeds=prefix, **extras)
    else:
        logits, aux = M.forward(params, cfg, tokens, positions, **extras)
    assert logits.shape == (2, tokens.shape[1], cfg.vocab_size)
    assert not jnp.isnan(logits).any(), f"{arch}: NaN logits"
    if cfg.num_experts:
        assert "moe_lb_loss" in aux


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch, smoke_models):
    """One LM-loss training step: grads flow, loss finite, params update."""
    cfg, params = smoke_models(arch)
    tokens, positions = _inputs(cfg)
    extras = _extras(params, cfg)

    def loss_fn(p):
        logits, aux = M.forward(p, cfg, tokens, positions, **extras)
        logp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32))
        tgt = tokens[:, 1:]
        nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
        mask = (positions[:, 1:] >= 0).astype(jnp.float32)
        loss = (nll * mask).sum() / mask.sum()
        if "moe_lb_loss" in aux:
            loss = loss + 0.01 * aux["moe_lb_loss"]
        return loss

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    gnorm = adamw.global_norm(grads)
    assert jnp.isfinite(gnorm) and gnorm > 0, f"{arch}: bad grad norm"
    ocfg = adamw.AdamWConfig(lr=1e-3)
    new_params, _, _ = adamw.update(ocfg, params, grads, adamw.init(params))
    # at least one param changed
    changed = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert changed, f"{arch}: update did not change params"


@pytest.mark.parametrize("arch", ARCHS)
def test_one_serve_step(arch, smoke_models):
    """Prefill + one decode step against the cache, no NaNs, correct shape."""
    cfg, params = smoke_models(arch)
    tokens, positions = _inputs(cfg)
    extras = _extras(params, cfg)
    B, T = tokens.shape
    caches = M.init_cache(cfg, B, T + 2)
    logits, caches = M.prefill(params, cfg, tokens, positions, caches, **extras)
    nxt = jnp.argmax(logits[:, -1:], axis=-1)
    npos = positions[:, -1:] + 1
    dlogits, caches = M.decode_step(params, cfg, nxt, npos, caches, T, **extras)
    assert dlogits.shape == (B, 1, cfg.vocab_size)
    assert not jnp.isnan(dlogits).any(), f"{arch}: NaN decode logits"
