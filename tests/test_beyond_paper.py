"""Beyond-paper features: adaptive lenience, use_pallas model paths,
trainer checkpoint/resume with a warm rollout cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SpecConfig
from repro.core.lenience import (AdaptiveLenience, FixedLenience,
                                 LinearWarmupLenience, make_schedule)
from repro.data.dataset import PromptDataset
from repro.data.tokenizer import VOCAB_SIZE
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig
from repro.rewards.mathgen import MathTaskConfig, generate_problems
from repro.rl.trainer import RLConfig, Trainer


# ------------------------------------------------------------------ lenience


def test_fixed_and_warmup_schedules():
    f = FixedLenience(2.0)
    assert f(0) == f(100) == 2.0
    w = LinearWarmupLenience(target=4.0, warmup_steps=10)
    assert w(0) == pytest.approx(1.0)
    assert w(10) == pytest.approx(4.0)
    assert 1.0 < w(5) < 4.0


def test_adaptive_lenience_controller():
    a = AdaptiveLenience(init=1.0, budget=0.05, gain=1.0, lo=1.0,
                         hi=np.e ** 2)
    # under budget: lenience grows
    for _ in range(3):
        a.update(0.0)
    assert a(0) > 1.0
    # way over budget: shrinks back to the floor
    for _ in range(20):
        a.update(1.0)
    assert a(0) == pytest.approx(1.0)
    assert make_schedule("adaptive", budget=0.1)(0) >= 1.0


def test_trainer_with_adaptive_lenience():
    cfg = ModelConfig(name="t", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=VOCAB_SIZE,
                      max_seq_len=128)
    ds = PromptDataset(generate_problems(MathTaskConfig(num_problems=8,
                                                        max_operand=5)),
                       max_prompt_len=10)
    rl = RLConfig(algo="grpo", group_size=2, prompts_per_batch=4,
                  max_new_tokens=6, optim=AdamWConfig(lr=2e-3))
    tr = Trainer(cfg, rl, SpecConfig(variant="spec", verify_impl="ref"), ds,
                 jax.random.PRNGKey(0),
                 lenience_schedule=AdaptiveLenience(init=1.0, budget=0.05,
                                                    gain=5.0))
    ls = [tr.train_step()["lenience"] for _ in range(3)]
    assert ls[-1] != ls[0]          # the controller moved lenience


# ------------------------------------------------------------------ pallas paths


@pytest.mark.parametrize("family_kw", [
    dict(num_heads=4, num_kv_heads=2),                       # gqa + flash
    dict(num_heads=0, num_kv_heads=0, block_kind="rwkv",
         rwkv_head_dim=16),                                  # rwkv + wkv
])
def test_use_pallas_matches_jnp(family_kw):
    cfg = ModelConfig(name="p", num_layers=2, d_model=64, d_ff=128,
                      vocab_size=64, **family_kw)
    params = M.init_lm(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 3, 64)
    pos = jnp.broadcast_to(jnp.arange(24, dtype=jnp.int32), (2, 24))
    pos = pos.at[0, :4].set(-1)
    a, _ = M.forward(params, cfg, tokens, pos)
    b, _ = M.forward(params, cfg, tokens, pos, use_pallas=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


# ------------------------------------------------------------------ resume


def test_trainer_checkpoint_resume(tmp_path):
    """Params + opt + rollout cache roundtrip; resumed trainer keeps reusing
    (no second cold start)."""
    from repro.checkpoint.io import (load_pytree, load_rollout_cache,
                                     save_pytree, save_rollout_cache)
    cfg = ModelConfig(name="t", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=VOCAB_SIZE,
                      max_seq_len=128)
    ds = PromptDataset(generate_problems(MathTaskConfig(num_problems=6,
                                                        max_operand=5)),
                       max_prompt_len=10)
    rl = RLConfig(algo="grpo", group_size=2, prompts_per_batch=3,
                  max_new_tokens=6, optim=AdamWConfig(lr=1e-3))
    spec = SpecConfig(variant="spec", verify_impl="ref")
    tr = Trainer(cfg, rl, spec, ds, jax.random.PRNGKey(0))
    for _ in range(2):
        tr.train_step()
    path = str(tmp_path / "ck")
    save_pytree(path, {"params": tr.params, "opt": tr.opt_state},
                {"step": tr.step_idx})
    save_rollout_cache(path, tr.cache)

    tr2 = Trainer(cfg, rl, spec, ds, jax.random.PRNGKey(0))
    state, meta = load_pytree(path)
    tr2.params = state["params"]
    tr2.opt_state = state["opt"]
    tr2.step_idx = meta["step"]
    tr2.cache = load_rollout_cache(path)
    assert len(tr2.cache) == len(tr.cache)
    m = tr2.train_step()
    # warm cache => reuse on the very first resumed step
    assert m.get("n_reused", 0) > 0 or m.get("draft_coverage", 0) > 0
